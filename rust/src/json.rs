//! Minimal JSON codec — parser + writer for the artifact manifest, golden
//! fixtures, structured traces and summary tables. (The image provides no
//! `serde`/`serde_json`; this substrate is built in-repo per DESIGN.md.)
//!
//! Supports the full JSON value grammar. Numbers are held as f64 (adequate
//! for every producer in this repo: python `json.dump` output and our own
//! traces). Object key order is preserved (Vec of pairs) so emitted files
//! diff cleanly.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are f64; object key order is insertion order.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (held as f64; integers round-trip up to 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object, as ordered key/value pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ---------------- accessors ----------------

    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` chained through a dotted path, e.g. `"contract.vocab"`.
    pub fn at(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for part in path.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    /// Numeric value (None for non-numbers).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Numeric value truncated to i64.
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    /// String value (None for non-strings).
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value (None for non-booleans).
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements (None for non-arrays).
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object key/value pairs in insertion order (None for non-objects).
    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    // ---------------- constructors ----------------

    /// An empty object.
    pub fn obj() -> Self {
        Json::Obj(Vec::new())
    }

    /// Append a key/value pair (no-op on non-objects); chainable.
    pub fn push(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        if let Json::Obj(pairs) = self {
            pairs.push((key.to_string(), value.into()));
        }
        self
    }

    /// Array of numbers from an f64 slice.
    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    /// Array of numbers from a u64 slice.
    pub fn from_u64_slice(xs: &[u64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    /// Object of numbers from a string-keyed map.
    pub fn from_str_map(m: &BTreeMap<String, f64>) -> Json {
        Json::Obj(m.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect())
    }

    // ---------------- writer ----------------

    /// Compact serialization (single line, no spaces).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Pretty serialization (two-space indent, trailing newline).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    push_indent(out, indent + 1);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn push_indent(out: &mut String, n: usize) {
    for _ in 0..n {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, x: f64) {
    if !x.is_finite() {
        // JSON has no inf/nan; clamp like python's json with allow_nan=False
        // would refuse — we choose a sentinel instead of invalid output.
        out.push_str(if x > 0.0 { "1e308" } else { "-1e308" });
    } else if x == x.trunc() && x.abs() < 9.0e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{}", x);
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---------------- parser ----------------

/// Parse a complete JSON document (trailing garbage is an error).
pub fn parse(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {} (got {:?})", c as char, self.i,
                        self.peek().map(|b| b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                other => return Err(format!("expected ',' or '}}' got {:?}", other.map(|b| b as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => return Err(format!("expected ',' or ']' got {:?}", other.map(|b| b as char))),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            // (no surrogate-pair handling — our producers never emit them)
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {:?}", other.map(|b| b as char))),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        text.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{text}': {e}"))
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let mut o = Json::obj();
        o.push("a", 1.5).push("b", "hi\n\"x\"").push("c", true);
        o.push("arr", Json::Arr(vec![Json::Num(1.0), Json::Null, Json::Str("z".into())]));
        let text = o.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn parses_python_json_dump_style() {
        let text = r#"{
  "contract": {"vocab": 512, "neg_inf": -1e+30},
  "artifacts": [{"name": "teacher_fused_s8", "bytes": 13300000}],
  "ok": true, "none": null
}"#;
        let v = parse(text).unwrap();
        assert_eq!(v.at("contract.vocab").unwrap().as_usize(), Some(512));
        assert_eq!(v.at("contract.neg_inf").unwrap().as_f64(), Some(-1e30));
        assert_eq!(
            v.get("artifacts").unwrap().as_arr().unwrap()[0].get("name").unwrap().as_str(),
            Some("teacher_fused_s8")
        );
        assert_eq!(v.get("none"), Some(&Json::Null));
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(-0.5).to_string(), "-0.5");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn pretty_output_reparses() {
        let mut o = Json::obj();
        o.push("x", Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]));
        let back = parse(&o.to_string_pretty()).unwrap();
        assert_eq!(back, o);
    }
}
