//! Small shared substrates: deterministic RNG, percentile summaries,
//! stage timers, and a seed-reporting randomized-testing helper
//! (the image has no `rand`/`proptest`/`criterion`).

pub mod arena;
pub mod bench;
pub mod idx;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;

pub use arena::{FeatRing, StepScratch};
pub use rng::SplitMix64;
pub use stats::Summary;
pub use timer::StageTimer;
