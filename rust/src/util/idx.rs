//! Checked index conversions — the typed face of the paper's
//! "negative indices removed by construction" claim (§3.2).
//!
//! The tree/cache index paths store slot and block coordinates as `u32`
//! precisely so a sentinel `-1` cannot exist. A raw `as usize` cast
//! erases that guarantee from the reader's view (and would silently
//! wrap if a signed value ever leaked in), so the `signed-cast`
//! static-analysis rule bans bare `as usize` in those modules
//! (`docs/STATIC_ANALYSIS.md`). These helpers are the blessed
//! replacements:
//!
//! * [`udx`] — infallible widening from an **unsigned** source. The
//!   signature is the proof: a signed argument does not compile, so
//!   every `udx` call site is a machine-checked "this index cannot be
//!   negative".
//! * [`checked_row`] / [`checked_col`] — fallible conversions for
//!   signed values arriving from outside the invariant boundary
//!   (wire payloads, artifact manifests, device outputs), returning a
//!   typed [`IndexError`] instead of wrapping.

use std::fmt;

/// A signed value failed conversion into an index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexError {
    /// The value was negative — the §3.2 invariants exclude this by
    /// construction, so seeing one means corrupt external input.
    Negative {
        /// What the index addresses ("row", "col", ...).
        what: &'static str,
        /// The offending value.
        got: i64,
    },
    /// The value exceeds the platform's `usize` range (32-bit targets).
    Overflow {
        /// What the index addresses.
        what: &'static str,
        /// The offending value.
        got: i64,
    },
}

impl fmt::Display for IndexError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Negative { what, got } => {
                write!(f, "negative {what} index {got} (§3.2 invariant violation)")
            }
            Self::Overflow { what, got } => {
                write!(f, "{what} index {got} exceeds the platform index range")
            }
        }
    }
}

impl std::error::Error for IndexError {}

/// Infallible widening of an unsigned index to `usize`. Taking `u32`
/// (never a signed type) is the point: the compiler rejects any call
/// site that could smuggle a negative value into an index path.
#[inline(always)]
pub fn udx(u: u32) -> usize {
    u as usize // lint: allow(signed-cast) — u32 source, widening is lossless
}

/// Fallible conversion of a signed row index arriving from outside the
/// invariant boundary (wire payloads, manifests, device outputs).
#[inline]
pub fn checked_row(i: i64) -> Result<usize, IndexError> {
    checked("row", i)
}

/// Fallible conversion of a signed column index (see [`checked_row`]).
#[inline]
pub fn checked_col(i: i64) -> Result<usize, IndexError> {
    checked("col", i)
}

/// Shared implementation: negative → [`IndexError::Negative`], beyond
/// `usize` → [`IndexError::Overflow`].
#[inline]
pub fn checked(what: &'static str, i: i64) -> Result<usize, IndexError> {
    if i < 0 {
        return Err(IndexError::Negative { what, got: i });
    }
    usize::try_from(i).map_err(|_| IndexError::Overflow { what, got: i })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn udx_widens() {
        assert_eq!(udx(0), 0);
        assert_eq!(udx(u32::MAX), u32::MAX as usize);
    }

    #[test]
    fn checked_accepts_non_negative() {
        assert_eq!(checked_row(0), Ok(0));
        assert_eq!(checked_col(17), Ok(17));
    }

    #[test]
    fn checked_rejects_negative_with_typed_error() {
        let e = checked_row(-1).unwrap_err();
        assert_eq!(e, IndexError::Negative { what: "row", got: -1 });
        assert!(e.to_string().contains("negative row index -1"), "{e}");
        assert!(matches!(checked_col(-7), Err(IndexError::Negative { what: "col", got: -7 })));
    }
}
