//! Reusable output arenas for the zero-allocation steady-state decode
//! path.
//!
//! * [`StepScratch`] — the caller-owned output block a
//!   [`crate::backend::ModelBackend`] step writes into (logits, features,
//!   new KV rows, optional probe output). Buffers grow to the high-water
//!   mark of the largest compiled S variant once and are reused for every
//!   subsequent call, so a steady-state speculative round performs no
//!   vocab- or cache-row-sized heap allocation.
//! * [`FeatRing`] — a fixed-capacity (token, feature-row) ring buffer
//!   replacing the old `Vec<(i32, Vec<f32>)>` "uncharted" queue, which
//!   cloned a `feat_dim` vector per committed token per round.
//!
//! These live in `util` (a leaf module) so both the backend layer and the
//! engine can depend on them without a layering cycle.
//!
//! # Batched layout
//!
//! A scratch can hold the outputs of a **fused multi-request step**
//! ([`StepScratch::prepare_batch`] with `batch > 1`): the row axis of
//! every buffer becomes `batch * s` rows, request `b` owning the
//! contiguous row block `[b*s, (b+1)*s)` (its *row offset* is `b * s`,
//! see [`StepScratch::row_offset`]). Layouts:
//!
//! ```text
//! logits  [B*S, V]          feats [B*S, F]
//! k_new   [L, B*S, H*Dh]    v_new [L, B*S, H*Dh]
//! ```
//!
//! [`StepScratch::scatter_from`] copies one request's rows out of a fused
//! scratch into a single-request scratch (the per-engine view), and
//! [`StepScratch::copy_request_from`] is the inverse (used by the default
//! sequential fallback of
//! [`crate::backend::ModelBackend::teacher_step_batch`]). Both are bounded
//! `copy_from_slice` loops over pre-sized buffers — no allocation.

/// Caller-provided reusable output block for one teacher/draft step
/// (single-request) or one fused batched step.
///
/// Layouts mirror the AOT module outputs: `logits [B*S, V]`,
/// `feats [B*S, F]`, `k_new`/`v_new [L, B*S, H, Dh]`, `attn_top1 [B*S, H]`
/// (probe builds only); `B = 1` for ordinary single-request steps. See
/// `backend/mod.rs` for the ownership and aliasing contract.
#[derive(Clone, Debug, Default)]
pub struct StepScratch {
    batch: usize,
    s: usize,
    vocab: usize,
    feat_dim: usize,
    layers: usize,
    heads: usize,
    d_head: usize,
    has_probe: bool,
    /// Teacher/draft logits, row-major `[batch * s, vocab]`.
    pub logits: Vec<f32>,
    /// Hidden feature rows, row-major `[batch * s, feat_dim]`.
    pub feats: Vec<f32>,
    /// New K rows, `[layers, batch * s, heads * d_head]`.
    pub k_new: Vec<f32>,
    /// New V rows, `[layers, batch * s, heads * d_head]`.
    pub v_new: Vec<f32>,
    /// Probe output (`[batch * s, heads]` top-1 attention columns);
    /// empty unless the step requested probing.
    pub attn_top1: Vec<i32>,
}

impl StepScratch {
    /// An empty scratch; the first [`StepScratch::prepare`] sizes it.
    pub fn new() -> Self {
        Self::default()
    }

    /// Resize for a single-request `s`-slot step. Buffers only ever grow
    /// in capacity; after the first call at the largest variant this is
    /// allocation-free. Contents are unspecified afterwards — the backend
    /// must write every live element it reports (padded-slot values are
    /// backend-defined).
    #[allow(clippy::too_many_arguments)]
    pub fn prepare(
        &mut self,
        s: usize,
        vocab: usize,
        feat_dim: usize,
        layers: usize,
        heads: usize,
        d_head: usize,
        probe: bool,
    ) {
        self.prepare_batch(1, s, vocab, feat_dim, layers, heads, d_head, probe);
    }

    /// Resize for a fused `batch`-request step of `s` padded slots per
    /// request. Same growth/overwrite rules as [`StepScratch::prepare`];
    /// request `b` owns rows `[b*s, (b+1)*s)` of every buffer.
    #[allow(clippy::too_many_arguments)]
    pub fn prepare_batch(
        &mut self,
        batch: usize,
        s: usize,
        vocab: usize,
        feat_dim: usize,
        layers: usize,
        heads: usize,
        d_head: usize,
        probe: bool,
    ) {
        assert!(batch >= 1, "batch must be >= 1");
        self.batch = batch;
        self.s = s;
        self.vocab = vocab;
        self.feat_dim = feat_dim;
        self.layers = layers;
        self.heads = heads;
        self.d_head = d_head;
        self.has_probe = probe;
        let rows = batch * s;
        let kv_row = heads * d_head;
        self.logits.resize(rows * vocab, 0.0);
        self.feats.resize(rows * feat_dim, 0.0);
        self.k_new.resize(layers * rows * kv_row, 0.0);
        self.v_new.resize(layers * rows * kv_row, 0.0);
        self.attn_top1.resize(if probe { rows * heads } else { 0 }, 0);
    }

    /// Padded slot count *per request* of the last step written into this
    /// scratch.
    pub fn s(&self) -> usize {
        self.s
    }

    /// Number of fused requests of the last step (1 for ordinary steps).
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// First row owned by request `b` (the per-request row offset of the
    /// batching contract: request `b` owns rows `[b*s, (b+1)*s)`).
    pub fn row_offset(&self, b: usize) -> usize {
        debug_assert!(b < self.batch.max(1));
        b * self.s
    }

    /// Logits row of (global) slot `i`; for batched scratches slot `i` of
    /// request `b` lives at `row_offset(b) + i`.
    pub fn logits_row(&self, i: usize) -> &[f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    /// Mutable form of [`StepScratch::logits_row`].
    pub fn logits_row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    /// Feature row of (global) slot `i`.
    pub fn feat_row(&self, i: usize) -> &[f32] {
        &self.feats[i * self.feat_dim..(i + 1) * self.feat_dim]
    }

    /// Mutable form of [`StepScratch::feat_row`].
    pub fn feat_row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.feats[i * self.feat_dim..(i + 1) * self.feat_dim]
    }

    /// Probe output (`[B*S, H]` top-1 attention columns), when requested.
    pub fn attn_top1(&self) -> Option<&[i32]> {
        if self.has_probe {
            Some(&self.attn_top1)
        } else {
            None
        }
    }

    /// Copy request `b`'s first `s_req` rows out of a fused batched
    /// scratch into `self`, re-preparing `self` exactly as if the backend
    /// had run that request alone at padded size `s_req`.
    ///
    /// `s_req <= fused.s()` (the request was padded up to the group's
    /// `S_max`); rows `[s_req, fused.s())` of the fused block are padding
    /// and are *not* copied — by the batching contract they were never
    /// attended and carry backend-defined garbage.
    pub fn scatter_from(&mut self, fused: &StepScratch, b: usize, s_req: usize) {
        assert!(b < fused.batch, "request {b} out of fused batch {}", fused.batch);
        assert!(s_req <= fused.s, "s_req {s_req} exceeds fused s {}", fused.s);
        self.prepare(
            s_req,
            fused.vocab,
            fused.feat_dim,
            fused.layers,
            fused.heads,
            fused.d_head,
            fused.has_probe,
        );
        let src0 = fused.row_offset(b);
        self.logits
            .copy_from_slice(&fused.logits[src0 * fused.vocab..(src0 + s_req) * fused.vocab]);
        self.feats
            .copy_from_slice(&fused.feats[src0 * fused.feat_dim..(src0 + s_req) * fused.feat_dim]);
        let row = fused.heads * fused.d_head;
        let fused_rows = fused.batch * fused.s;
        for l in 0..fused.layers {
            let src = (l * fused_rows + src0) * row;
            let dst = l * s_req * row;
            self.k_new[dst..dst + s_req * row]
                .copy_from_slice(&fused.k_new[src..src + s_req * row]);
            self.v_new[dst..dst + s_req * row]
                .copy_from_slice(&fused.v_new[src..src + s_req * row]);
        }
        if fused.has_probe {
            let h = fused.heads;
            self.attn_top1
                .copy_from_slice(&fused.attn_top1[src0 * h..(src0 + s_req) * h]);
        }
    }

    /// Inverse of [`StepScratch::scatter_from`]: copy a single-request
    /// scratch (`src.batch() == 1`, `src.s() == self.s()`) into request
    /// `b`'s row block of this fused scratch. Used by the sequential
    /// fallback of [`crate::backend::ModelBackend::teacher_step_batch`].
    pub fn copy_request_from(&mut self, b: usize, src: &StepScratch) {
        assert_eq!(src.batch, 1, "source must be a single-request scratch");
        assert_eq!(src.s, self.s, "source rows {} != fused rows-per-request {}", src.s, self.s);
        assert_eq!((src.vocab, src.feat_dim), (self.vocab, self.feat_dim), "dims mismatch");
        assert_eq!(
            (src.layers, src.heads, src.d_head),
            (self.layers, self.heads, self.d_head),
            "KV dims mismatch"
        );
        assert!(b < self.batch, "request {b} out of fused batch {}", self.batch);
        let dst0 = self.row_offset(b);
        let s = self.s;
        self.logits[dst0 * self.vocab..(dst0 + s) * self.vocab].copy_from_slice(&src.logits);
        self.feats[dst0 * self.feat_dim..(dst0 + s) * self.feat_dim].copy_from_slice(&src.feats);
        let row = self.heads * self.d_head;
        let rows = self.batch * self.s;
        for l in 0..self.layers {
            let dst = (l * rows + dst0) * row;
            let srco = l * s * row;
            self.k_new[dst..dst + s * row].copy_from_slice(&src.k_new[srco..srco + s * row]);
            self.v_new[dst..dst + s * row].copy_from_slice(&src.v_new[srco..srco + s * row]);
        }
        if self.has_probe && src.has_probe {
            let h = self.heads;
            self.attn_top1[dst0 * h..(dst0 + s) * h].copy_from_slice(&src.attn_top1);
        }
    }
}

/// Fixed-capacity FIFO of (token, feature-row) pairs with inline feature
/// storage — the draft chain-refresh queue.
#[derive(Clone, Debug)]
pub struct FeatRing {
    feat_dim: usize,
    cap: usize,
    tokens: Vec<i32>,
    feats: Vec<f32>,
    head: usize,
    len: usize,
}

impl FeatRing {
    /// `cap` must cover the worst-case backlog (the committed-cache
    /// capacity bounds it: every queued token is a committed token).
    pub fn with_capacity(cap: usize, feat_dim: usize) -> Self {
        Self {
            feat_dim,
            cap,
            tokens: vec![0; cap],
            feats: vec![0.0; cap * feat_dim],
            head: 0,
            len: 0,
        }
    }

    /// Number of queued entries.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the queue is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Drop every entry (capacity kept).
    pub fn clear(&mut self) {
        self.head = 0;
        self.len = 0;
    }

    /// Copy `feat` (must be `feat_dim` long) into the next slot.
    pub fn push(&mut self, token: i32, feat: &[f32]) {
        assert!(self.len < self.cap, "FeatRing overflow: cap {}", self.cap);
        assert_eq!(feat.len(), self.feat_dim, "feature row width mismatch");
        let idx = (self.head + self.len) % self.cap;
        self.tokens[idx] = token;
        self.feats[idx * self.feat_dim..(idx + 1) * self.feat_dim].copy_from_slice(feat);
        self.len += 1;
    }

    /// Pop the front entry; the feature slice stays valid until the next
    /// `push` (pops never overwrite).
    pub fn pop_front(&mut self) -> Option<(i32, &[f32])> {
        if self.len == 0 {
            return None;
        }
        let idx = self.head;
        self.head = (self.head + 1) % self.cap;
        self.len -= 1;
        let f = &self.feats[idx * self.feat_dim..(idx + 1) * self.feat_dim];
        Some((self.tokens[idx], f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_rows_and_reuse() {
        let mut s = StepScratch::new();
        s.prepare(2, 3, 2, 1, 1, 4, false);
        s.logits.copy_from_slice(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        s.feats.copy_from_slice(&[9.0, 8.0, 7.0, 6.0]);
        assert_eq!(s.logits_row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(s.feat_row(0), &[9.0, 8.0]);
        assert!(s.attn_top1().is_none());
        assert_eq!(s.s(), 2);
        assert_eq!(s.batch(), 1);
        // shrink then regrow: no new capacity needed
        let cap_before = s.logits.capacity();
        s.prepare(1, 3, 2, 1, 1, 4, true);
        assert_eq!(s.logits.len(), 3);
        assert!(s.attn_top1().is_some());
        s.prepare(2, 3, 2, 1, 1, 4, false);
        assert_eq!(s.logits.capacity(), cap_before);
    }

    #[test]
    fn batched_scratch_row_offsets() {
        let mut s = StepScratch::new();
        s.prepare_batch(3, 2, 2, 1, 1, 1, false);
        assert_eq!(s.batch(), 3);
        assert_eq!(s.s(), 2);
        assert_eq!(s.row_offset(2), 4);
        assert_eq!(s.logits.len(), 3 * 2 * 2);
        // write request 1's first row through the global accessor
        s.logits_row_mut(s.row_offset(1)).copy_from_slice(&[7.0, 8.0]);
        assert_eq!(s.logits_row(2), &[7.0, 8.0]);
    }

    #[test]
    fn scatter_and_copy_request_roundtrip() {
        // fused scratch: B=2, S=2, V=2, F=1, L=2, H=1, Dh=1
        let mut fused = StepScratch::new();
        fused.prepare_batch(2, 2, 2, 1, 2, 1, 1, false);
        for (i, x) in fused.logits.iter_mut().enumerate() {
            *x = i as f32;
        }
        for (i, x) in fused.feats.iter_mut().enumerate() {
            *x = 100.0 + i as f32;
        }
        for (i, x) in fused.k_new.iter_mut().enumerate() {
            *x = 200.0 + i as f32;
        }
        for (i, x) in fused.v_new.iter_mut().enumerate() {
            *x = 300.0 + i as f32;
        }
        // request 1, full s_req = 2
        let mut one = StepScratch::new();
        one.scatter_from(&fused, 1, 2);
        assert_eq!(one.batch(), 1);
        assert_eq!(one.s(), 2);
        // logits rows 2..4 of the fused block
        assert_eq!(one.logits, &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(one.feats, &[102.0, 103.0]);
        // k_new: fused layout [L=2, B*S=4, row=1]; request 1 rows are
        // global rows {2, 3} per layer -> elements {2, 3, 6, 7}
        assert_eq!(one.k_new, &[202.0, 203.0, 206.0, 207.0]);
        assert_eq!(one.v_new, &[302.0, 303.0, 306.0, 307.0]);

        // round-trip back into a fresh fused block at the same offset
        let mut fused2 = StepScratch::new();
        fused2.prepare_batch(2, 2, 2, 1, 2, 1, 1, false);
        fused2.logits.fill(-1.0);
        fused2.k_new.fill(-1.0);
        fused2.copy_request_from(1, &one);
        assert_eq!(&fused2.logits[4..8], &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(fused2.logits[0], -1.0, "request 0 untouched");
        assert_eq!(fused2.k_new[2], 202.0);
        assert_eq!(fused2.k_new[6], 206.0);
        assert_eq!(fused2.k_new[0], -1.0);
    }

    #[test]
    fn scatter_truncates_to_requested_rows() {
        let mut fused = StepScratch::new();
        fused.prepare_batch(2, 4, 2, 1, 1, 1, 1, false);
        for (i, x) in fused.logits.iter_mut().enumerate() {
            *x = i as f32;
        }
        let mut one = StepScratch::new();
        one.scatter_from(&fused, 0, 2); // only 2 of 4 padded rows
        assert_eq!(one.s(), 2);
        assert_eq!(one.logits, &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn ring_fifo_and_wraparound() {
        let mut r = FeatRing::with_capacity(3, 2);
        r.push(10, &[1.0, 2.0]);
        r.push(11, &[3.0, 4.0]);
        assert_eq!(r.len(), 2);
        {
            let (t, f) = r.pop_front().unwrap();
            assert_eq!(t, 10);
            assert_eq!(f, &[1.0, 2.0]);
        }
        r.push(12, &[5.0, 6.0]);
        r.push(13, &[7.0, 8.0]); // wraps
        assert_eq!(r.len(), 3);
        let order: Vec<i32> = std::iter::from_fn(|| r.pop_front().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![11, 12, 13]);
        assert!(r.is_empty());
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn ring_rejects_overflow() {
        let mut r = FeatRing::with_capacity(1, 1);
        r.push(1, &[0.0]);
        r.push(2, &[0.0]);
    }
}
