//! Reusable output arenas for the zero-allocation steady-state decode
//! path.
//!
//! * [`StepScratch`] — the caller-owned output block a
//!   [`crate::backend::ModelBackend`] step writes into (logits, features,
//!   new KV rows, optional probe output). Buffers grow to the high-water
//!   mark of the largest compiled S variant once and are reused for every
//!   subsequent call, so a steady-state speculative round performs no
//!   vocab- or cache-row-sized heap allocation.
//! * [`FeatRing`] — a fixed-capacity (token, feature-row) ring buffer
//!   replacing the old `Vec<(i32, Vec<f32>)>` "uncharted" queue, which
//!   cloned a `feat_dim` vector per committed token per round.
//!
//! These live in `util` (a leaf module) so both the backend layer and the
//! engine can depend on them without a layering cycle.

/// Caller-provided reusable output block for one teacher/draft step.
///
/// Layouts mirror the AOT module outputs: `logits [S, V]`,
/// `feats [S, F]`, `k_new`/`v_new [L, S, H, Dh]`, `attn_top1 [S, H]`
/// (probe builds only). See `backend/mod.rs` for the ownership and
/// aliasing contract.
#[derive(Clone, Debug, Default)]
pub struct StepScratch {
    s: usize,
    vocab: usize,
    feat_dim: usize,
    has_probe: bool,
    pub logits: Vec<f32>,
    pub feats: Vec<f32>,
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
    pub attn_top1: Vec<i32>,
}

impl StepScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Resize for an `s`-slot step. Buffers only ever grow in capacity;
    /// after the first call at the largest variant this is allocation-free.
    /// Contents are unspecified afterwards — the backend must write every
    /// live element it reports (padded-slot values are backend-defined).
    #[allow(clippy::too_many_arguments)]
    pub fn prepare(
        &mut self,
        s: usize,
        vocab: usize,
        feat_dim: usize,
        layers: usize,
        heads: usize,
        d_head: usize,
        probe: bool,
    ) {
        self.s = s;
        self.vocab = vocab;
        self.feat_dim = feat_dim;
        self.has_probe = probe;
        let kv_row = heads * d_head;
        self.logits.resize(s * vocab, 0.0);
        self.feats.resize(s * feat_dim, 0.0);
        self.k_new.resize(layers * s * kv_row, 0.0);
        self.v_new.resize(layers * s * kv_row, 0.0);
        self.attn_top1.resize(if probe { s * heads } else { 0 }, 0);
    }

    /// Padded slot count of the last step written into this scratch.
    pub fn s(&self) -> usize {
        self.s
    }

    /// Logits row of slot `i`.
    pub fn logits_row(&self, i: usize) -> &[f32] {
        &self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    pub fn logits_row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.logits[i * self.vocab..(i + 1) * self.vocab]
    }

    /// Feature row of slot `i`.
    pub fn feat_row(&self, i: usize) -> &[f32] {
        &self.feats[i * self.feat_dim..(i + 1) * self.feat_dim]
    }

    pub fn feat_row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.feats[i * self.feat_dim..(i + 1) * self.feat_dim]
    }

    /// Probe output (`[S, H]` top-1 attention columns), when requested.
    pub fn attn_top1(&self) -> Option<&[i32]> {
        if self.has_probe {
            Some(&self.attn_top1)
        } else {
            None
        }
    }
}

/// Fixed-capacity FIFO of (token, feature-row) pairs with inline feature
/// storage — the draft chain-refresh queue.
#[derive(Clone, Debug)]
pub struct FeatRing {
    feat_dim: usize,
    cap: usize,
    tokens: Vec<i32>,
    feats: Vec<f32>,
    head: usize,
    len: usize,
}

impl FeatRing {
    /// `cap` must cover the worst-case backlog (the committed-cache
    /// capacity bounds it: every queued token is a committed token).
    pub fn with_capacity(cap: usize, feat_dim: usize) -> Self {
        Self {
            feat_dim,
            cap,
            tokens: vec![0; cap],
            feats: vec![0.0; cap * feat_dim],
            head: 0,
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn clear(&mut self) {
        self.head = 0;
        self.len = 0;
    }

    /// Copy `feat` (must be `feat_dim` long) into the next slot.
    pub fn push(&mut self, token: i32, feat: &[f32]) {
        assert!(self.len < self.cap, "FeatRing overflow: cap {}", self.cap);
        assert_eq!(feat.len(), self.feat_dim, "feature row width mismatch");
        let idx = (self.head + self.len) % self.cap;
        self.tokens[idx] = token;
        self.feats[idx * self.feat_dim..(idx + 1) * self.feat_dim].copy_from_slice(feat);
        self.len += 1;
    }

    /// Pop the front entry; the feature slice stays valid until the next
    /// `push` (pops never overwrite).
    pub fn pop_front(&mut self) -> Option<(i32, &[f32])> {
        if self.len == 0 {
            return None;
        }
        let idx = self.head;
        self.head = (self.head + 1) % self.cap;
        self.len -= 1;
        let f = &self.feats[idx * self.feat_dim..(idx + 1) * self.feat_dim];
        Some((self.tokens[idx], f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_rows_and_reuse() {
        let mut s = StepScratch::new();
        s.prepare(2, 3, 2, 1, 1, 4, false);
        s.logits.copy_from_slice(&[0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        s.feats.copy_from_slice(&[9.0, 8.0, 7.0, 6.0]);
        assert_eq!(s.logits_row(1), &[3.0, 4.0, 5.0]);
        assert_eq!(s.feat_row(0), &[9.0, 8.0]);
        assert!(s.attn_top1().is_none());
        assert_eq!(s.s(), 2);
        // shrink then regrow: no new capacity needed
        let cap_before = s.logits.capacity();
        s.prepare(1, 3, 2, 1, 1, 4, true);
        assert_eq!(s.logits.len(), 3);
        assert!(s.attn_top1().is_some());
        s.prepare(2, 3, 2, 1, 1, 4, false);
        assert_eq!(s.logits.capacity(), cap_before);
    }

    #[test]
    fn ring_fifo_and_wraparound() {
        let mut r = FeatRing::with_capacity(3, 2);
        r.push(10, &[1.0, 2.0]);
        r.push(11, &[3.0, 4.0]);
        assert_eq!(r.len(), 2);
        {
            let (t, f) = r.pop_front().unwrap();
            assert_eq!(t, 10);
            assert_eq!(f, &[1.0, 2.0]);
        }
        r.push(12, &[5.0, 6.0]);
        r.push(13, &[7.0, 8.0]); // wraps
        assert_eq!(r.len(), 3);
        let order: Vec<i32> = std::iter::from_fn(|| r.pop_front().map(|(t, _)| t)).collect();
        assert_eq!(order, vec![11, 12, 13]);
        assert!(r.is_empty());
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn ring_rejects_overflow() {
        let mut r = FeatRing::with_capacity(1, 1);
        r.push(1, &[0.0]);
        r.push(2, &[0.0]);
    }
}
