//! Per-stage wall-clock timers — the instrumentation behind the paper's E3
//! overhead breakdown (Fig 5). Stage names are stable identifiers that flow
//! into the structured traces.
//!
//! This module is one of the three audited homes of wall-clock reads
//! (`util/timer.rs`, `util/bench.rs`, `runtime/pjrt.rs`): the
//! `wall-clock` static-analysis rule bans `Instant::now` everywhere
//! else so scheduler/replay/worker logic stays on the virtual clock
//! (`docs/STATIC_ANALYSIS.md`). Code that needs to *measure* elapsed
//! wall time (never to make scheduling decisions) uses [`Stopwatch`].

use std::collections::BTreeMap;
use std::time::Instant;

/// An elapsed-time measurement anchored at [`Stopwatch::start`] — the
/// only way non-allowlisted modules read the wall clock. Deliberately
/// minimal: it can report durations (instrumentation) but cannot be
/// compared against a future deadline, so it cannot leak wall-clock
/// *decisions* into scheduler/replay code (which must stay on the
/// virtual clock — see `coordinator::ContinuousScheduler::advance_clock`).
#[derive(Clone, Copy, Debug)]
pub struct Stopwatch(Instant);

impl Stopwatch {
    /// Anchor a measurement at the current instant.
    pub fn start() -> Self {
        Self(Instant::now())
    }

    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn elapsed_secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Seconds between an `earlier` stopwatch's anchor and this one's
    /// (0 if `earlier` was actually started later).
    pub fn secs_since(&self, earlier: &Stopwatch) -> f64 {
        self.0.saturating_duration_since(earlier.0).as_secs_f64()
    }
}

/// The decode-loop stages the paper's E3 experiment attributes time to.
/// `verify` is the host-blocked share of a fused launch (begin + await);
/// `verify_hidden` is the in-flight window the pipelined serve loop
/// spent on other slots' host work instead of waiting — overlap actually
/// achieved, recorded only when a launch was truly overlapped.
pub const STAGES: &[&str] = &[
    "prefill",
    "draft_expand",
    "tensorize",
    "mask_build",
    "verify",
    "verify_hidden",
    "accept",
    "commit",
];

/// Accumulates per-stage durations (seconds) and call counts.
#[derive(Clone, Debug, Default)]
pub struct StageTimer {
    /// Accumulated seconds per stage name.
    pub seconds: BTreeMap<String, f64>,
    /// Accumulation count per stage name.
    pub calls: BTreeMap<String, u64>,
    enabled: bool,
}

impl StageTimer {
    /// A timer; disabled timers record nothing and cost nothing.
    pub fn new(enabled: bool) -> Self {
        Self { enabled, ..Default::default() }
    }

    /// Time a closure under a stage label.
    pub fn time<T>(&mut self, stage: &str, f: impl FnOnce() -> T) -> T {
        if !self.enabled {
            return f();
        }
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed().as_secs_f64());
        out
    }

    /// Add `secs` to a stage (no-op when disabled).
    pub fn add(&mut self, stage: &str, secs: f64) {
        if !self.enabled {
            return;
        }
        *self.seconds.entry(stage.to_string()).or_insert(0.0) += secs;
        *self.calls.entry(stage.to_string()).or_insert(0) += 1;
    }

    /// Whether this timer records anything.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Merge another timer's totals and call counts into this one.
    pub fn merge(&mut self, other: &StageTimer) {
        for (k, v) in &other.seconds {
            *self.seconds.entry(k.clone()).or_insert(0.0) += v;
        }
        for (k, v) in &other.calls {
            *self.calls.entry(k.clone()).or_insert(0) += v;
        }
    }

    /// Sum of all stage totals, seconds.
    pub fn total(&self) -> f64 {
        self.seconds.values().sum()
    }

    /// Mean seconds per call for a stage (0 if never hit).
    pub fn mean(&self, stage: &str) -> f64 {
        let s = self.seconds.get(stage).copied().unwrap_or(0.0);
        let c = self.calls.get(stage).copied().unwrap_or(0);
        if c == 0 {
            0.0
        } else {
            s / c as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timer_is_free_and_empty() {
        let mut t = StageTimer::new(false);
        let v = t.time("verify", || 41 + 1);
        assert_eq!(v, 42);
        assert!(t.seconds.is_empty());
    }

    #[test]
    fn accumulates_and_counts() {
        let mut t = StageTimer::new(true);
        t.add("commit", 0.25);
        t.add("commit", 0.75);
        assert_eq!(t.calls["commit"], 2);
        assert!((t.seconds["commit"] - 1.0).abs() < 1e-12);
        assert!((t.mean("commit") - 0.5).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_measures_forward_time() {
        let a = Stopwatch::start();
        let b = Stopwatch::start();
        assert!(a.elapsed_secs() >= 0.0);
        assert!(b.secs_since(&a) >= 0.0);
        assert_eq!(a.secs_since(&b), 0.0, "earlier-than-anchor saturates to 0");
    }

    #[test]
    fn merge_adds_both_maps() {
        let mut a = StageTimer::new(true);
        a.add("verify", 1.0);
        let mut b = StageTimer::new(true);
        b.add("verify", 2.0);
        b.add("commit", 3.0);
        a.merge(&b);
        assert!((a.seconds["verify"] - 3.0).abs() < 1e-12);
        assert_eq!(a.calls["commit"], 1);
        assert!((a.total() - 6.0).abs() < 1e-12);
    }
}
