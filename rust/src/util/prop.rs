//! Minimal property-testing substrate (the image has no `proptest`).
//!
//! `for_cases(n, seed, |g| ...)` runs `n` randomized cases; on failure the
//! panic message carries the case seed so the exact case replays with
//! `replay(seed, |g| ...)`. No shrinking — cases are kept small instead.

use super::rng::{splitmix64, SplitMix64};

/// Case generator handed to property bodies.
pub struct Gen {
    /// The case's deterministic stream (use directly for raw draws).
    pub rng: SplitMix64,
    /// The case's replay seed (printed on failure).
    pub seed: u64,
}

impl Gen {
    /// Uniform usize in `[lo, hi)`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as u64, hi as u64) as usize
    }

    /// Bernoulli draw with probability `p`.
    pub fn bool_p(&mut self, p: f64) -> bool {
        self.rng.f64_unit() < p
    }

    /// Uniform f32 in `[-1, 1)`.
    pub fn f32_pm1(&mut self) -> f32 {
        self.rng.f32_pm1()
    }

    /// A vector of length in [lo_len, hi_len) with elements in [lo, hi).
    pub fn vec_usize(&mut self, lo_len: usize, hi_len: usize, lo: usize, hi: usize) -> Vec<usize> {
        let n = self.usize_in(lo_len, hi_len);
        (0..n).map(|_| self.usize_in(lo, hi)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_in(0, xs.len())]
    }
}

/// Run `n` randomized cases of a property. Panics (with the replay seed in
/// the message) as soon as one case panics.
pub fn for_cases(n: usize, seed: u64, mut body: impl FnMut(&mut Gen)) {
    for i in 0..n {
        let case_seed = splitmix64(seed ^ (i as u64).wrapping_mul(0x9E37_79B9));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut g = Gen { rng: SplitMix64::new(case_seed), seed: case_seed };
            body(&mut g);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed on case {i} (replay seed {case_seed:#x}): {msg}");
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay(case_seed: u64, mut body: impl FnMut(&mut Gen)) {
    let mut g = Gen { rng: SplitMix64::new(case_seed), seed: case_seed };
    body(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cases_are_deterministic_per_seed() {
        let mut seen = Vec::new();
        for_cases(5, 42, |g| seen.push(g.rng.next_u64()));
        let mut seen2 = Vec::new();
        for_cases(5, 42, |g| seen2.push(g.rng.next_u64()));
        assert_eq!(seen, seen2);
    }

    #[test]
    fn failure_reports_replay_seed() {
        let err = std::panic::catch_unwind(|| {
            for_cases(50, 7, |g| {
                let v = g.usize_in(0, 100);
                assert!(v < 5, "v was {v}");
            })
        })
        .unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("replay seed"), "{msg}");
    }

    #[test]
    fn gen_ranges_hold() {
        for_cases(50, 1, |g| {
            let v = g.usize_in(3, 9);
            assert!((3..9).contains(&v));
            let xs = g.vec_usize(1, 4, 10, 20);
            assert!(!xs.is_empty() && xs.len() < 4);
            assert!(xs.iter().all(|x| (10..20).contains(x)));
        });
    }
}
