//! Percentile summaries and histograms — the statistics the paper reports
//! (mean / p50 / p90 / p99 across turns, accept_L and accept_pos series).

/// A mean/percentile summary over a sample set.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Finite samples aggregated.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Median (interpolated).
    pub p50: f64,
    /// 90th percentile (interpolated).
    pub p90: f64,
    /// 99th percentile (interpolated).
    pub p99: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Build from raw samples. Empty input yields an all-zero summary.
    pub fn from(samples: &[f64]) -> Self {
        if samples.is_empty() {
            return Self { n: 0, mean: 0.0, p50: 0.0, p90: 0.0, p99: 0.0, min: 0.0, max: 0.0 };
        }
        let mut v: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        Self {
            n: v.len(),
            mean,
            p50: percentile_sorted(&v, 0.50),
            p90: percentile_sorted(&v, 0.90),
            p99: percentile_sorted(&v, 0.99),
            min: v[0],
            max: v[v.len() - 1],
        }
    }

    /// One row of the paper-style table: `mean p50 p90 p99`.
    pub fn row(&self) -> String {
        format!("{:>8.2} {:>8.2} {:>8.2} {:>8.2}", self.mean, self.p50, self.p90, self.p99)
    }
}

/// Linear-interpolated percentile over a pre-sorted slice, q in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Fixed-bucket histogram (used for accept_pos, length distributions,
/// and the Fig-7 attention-location buckets).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Upper bound of each bucket (ascending).
    pub edges: Vec<f64>,
    /// Per-bucket counts; the final bucket is the overflow.
    pub counts: Vec<u64>,
    /// Total samples added.
    pub total: u64,
}

impl Histogram {
    /// `edges` are the upper bounds of each bucket; a final overflow
    /// bucket catches everything above the last edge.
    pub fn new(edges: Vec<f64>) -> Self {
        let n = edges.len() + 1;
        Self { edges, counts: vec![0; n], total: 0 }
    }

    /// Add one sample to its bucket.
    pub fn add(&mut self, x: f64) {
        let idx = self.edges.iter().position(|e| x <= *e).unwrap_or(self.edges.len());
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Fraction of samples in bucket `idx` (0 when empty).
    pub fn fraction(&self, idx: usize) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.counts[idx] as f64 / self.total as f64
        }
    }
}

/// Position-indexed acceptance counter: accept_pos[i] = P(accept | position i)
/// — paper Fig 3. `offered[i]` counts verification steps whose tree had a
/// depth-(i+1) candidate on the accepted path's continuation.
#[derive(Clone, Debug, Default)]
pub struct AcceptPos {
    /// Rounds whose tree offered a candidate at depth i+1.
    pub offered: Vec<u64>,
    /// Rounds that accepted through depth i+1.
    pub accepted: Vec<u64>,
}

impl AcceptPos {
    /// Record one round: accepted `accepted_len` of `offered_depth`
    /// offered positions.
    pub fn record(&mut self, accepted_len: usize, offered_depth: usize) {
        if self.offered.len() < offered_depth {
            self.offered.resize(offered_depth, 0);
            self.accepted.resize(offered_depth, 0);
        }
        for i in 0..offered_depth {
            self.offered[i] += 1;
            if i < accepted_len {
                self.accepted[i] += 1;
            }
        }
    }

    /// Merge another counter set into this one (index-wise sums).
    pub fn merge(&mut self, other: &AcceptPos) {
        if self.offered.len() < other.offered.len() {
            self.offered.resize(other.offered.len(), 0);
            self.accepted.resize(other.offered.len(), 0);
        }
        for i in 0..other.offered.len() {
            self.offered[i] += other.offered[i];
            self.accepted[i] += other.accepted[i];
        }
    }

    /// Per-position acceptance rates `accepted[i] / offered[i]`.
    pub fn rates(&self) -> Vec<f64> {
        self.offered
            .iter()
            .zip(&self.accepted)
            .map(|(o, a)| if *o == 0 { 0.0 } else { *a as f64 / *o as f64 })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::from(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn summary_empty_is_zero() {
        let s = Summary::from(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&v, 0.9) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new(vec![16.0, 64.0, 256.0]);
        for x in [1.0, 20.0, 100.0, 1000.0, 5.0] {
            h.add(x);
        }
        assert_eq!(h.counts, vec![2, 1, 1, 1]);
        assert!((h.fraction(0) - 0.4).abs() < 1e-12);
    }

    #[test]
    fn accept_pos_rates() {
        let mut a = AcceptPos::default();
        a.record(2, 4); // accepted first 2 of 4 offered depths
        a.record(1, 4);
        let r = a.rates();
        assert_eq!(r.len(), 4);
        assert!((r[0] - 1.0).abs() < 1e-12);
        assert!((r[1] - 0.5).abs() < 1e-12);
        assert_eq!(r[3], 0.0);
    }

    #[test]
    fn accept_pos_merge() {
        let mut a = AcceptPos::default();
        a.record(1, 2);
        let mut b = AcceptPos::default();
        b.record(3, 3);
        a.merge(&b);
        assert_eq!(a.offered, vec![2, 2, 1]);
        assert_eq!(a.accepted, vec![2, 1, 1]);
    }
}
