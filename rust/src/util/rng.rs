//! splitmix64 — the single deterministic randomness source of the repo.
//!
//! Bit-identical to `python/compile/grammar.py::splitmix64` and
//! `python/compile/aot.py::Stream`: the grammar workload, the golden
//! fixtures and every randomized test depend on this parity (checked by
//! `workload::grammar` tests against `artifacts/manifest.json`).

/// Stateless splitmix64 finalizer.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A splitmix64 sequential stream (mirrors `aot.py::Stream`).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// A stream starting from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f32 in [-1, 1) — parity with `aot.py::Stream.f32`.
    #[inline]
    pub fn f32_pm1(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f64 / (1u64 << 24) as f64 * 2.0 - 1.0) as f32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi.saturating_sub(lo))
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.f64_unit() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r < 0.0 {
                return i;
            }
        }
        weights.len().saturating_sub(1)
    }

    /// Normal-ish sample (sum of uniforms; adequate for synthetic jitter).
    pub fn gauss(&mut self) -> f64 {
        let mut s = 0.0;
        for _ in 0..12 {
            s += self.f64_unit();
        }
        s - 6.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_value_matches_reference() {
        // canonical splitmix64(0) first output; also asserted in python.
        assert_eq!(splitmix64(0), 0xE220_A839_7B1D_CDAF);
    }

    #[test]
    fn stream_matches_stateless_chain() {
        let mut s = SplitMix64::new(7);
        let a = s.next_u64();
        assert_eq!(a, splitmix64(7));
    }

    #[test]
    fn f32_pm1_in_range() {
        let mut s = SplitMix64::new(3);
        for _ in 0..1000 {
            let v = s.f32_pm1();
            assert!((-1.0..1.0).contains(&v));
        }
    }

    #[test]
    fn weighted_respects_zero_weights() {
        let mut s = SplitMix64::new(9);
        for _ in 0..100 {
            let i = s.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }
}
