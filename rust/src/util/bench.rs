//! Minimal benchmark harness (the image has no `criterion`): warmup +
//! timed iterations, median-of-samples reporting, and a `BENCH_FILTER`
//! env filter. Used by every target under `rust/benches/`.

use std::time::Instant;

/// One benchmark case result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark case name.
    pub name: String,
    /// Total timed iterations.
    pub iters: u64,
    /// Mean ns/iteration across sample batches.
    pub mean_ns: f64,
    /// Median ns/iteration across sample batches.
    pub median_ns: f64,
    /// Fastest sample batch, ns/iteration.
    pub min_ns: f64,
}

impl BenchResult {
    /// Human-readable median time per iteration.
    pub fn per_iter(&self) -> String {
        fmt_ns(self.median_ns)
    }
}

/// Format a nanosecond count with an adaptive unit (ns/µs/ms/s).
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Runs `f` repeatedly: a few warmup calls, then `samples` timed batches
/// sized so each batch takes ~`target_batch_ms`. Prints one line.
pub fn bench(name: &str, target_batch_ms: f64, samples: usize, mut f: impl FnMut()) -> Option<BenchResult> {
    if let Ok(filter) = std::env::var("BENCH_FILTER") {
        if !name.contains(&filter) {
            return None;
        }
    }
    // warmup + calibration
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let per_batch = ((target_batch_ms / 1e3 / once).ceil() as u64).clamp(1, 1_000_000);
    for _ in 0..per_batch.min(3) {
        f();
    }
    let mut times: Vec<f64> = Vec::with_capacity(samples);
    let mut total_iters = 0u64;
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..per_batch {
            f();
        }
        times.push(t.elapsed().as_secs_f64() / per_batch as f64 * 1e9);
        total_iters += per_batch;
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let result = BenchResult {
        name: name.to_string(),
        iters: total_iters,
        mean_ns: times.iter().sum::<f64>() / times.len() as f64,
        median_ns: times[times.len() / 2],
        min_ns: times[0],
    };
    println!(
        "{:<56} {:>12}/iter  (min {:>10}, {} iters)",
        result.name,
        result.per_iter(),
        fmt_ns(result.min_ns),
        result.iters
    );
    Some(result)
}

/// Black-box: defeat the optimizer without nightly intrinsics.
#[inline]
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_reasonable_numbers() {
        let r = bench("noop_add", 1.0, 3, || {
            black_box(1 + 1);
        })
        .unwrap();
        assert!(r.median_ns < 1e6);
        assert!(r.iters > 0);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(12.0e3).contains("µs"));
        assert!(fmt_ns(12.0e6).contains("ms"));
        assert!(fmt_ns(12.0e9).contains("s"));
    }
}
