//! Minimal argument parser: positional args, `--key value` flags and
//! `--switch` booleans. Unknown-flag detection is done per-command via
//! [`Args::ensure_known`] so typos fail fast instead of being ignored.
//!
//! On/off flags are **typed**: they are declared once in
//! [`TOGGLE_FLAGS`], which both registers them as value-taking (so
//! `--pipelining off` can never silently parse as a switch plus a stray
//! positional — the historical failure mode) and routes them through
//! [`Args::get_toggle`] / [`Toggle`], whose rejection error is the
//! typed [`ConfigError::BadToggle`].

use crate::config::ConfigError;
use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: positionals, `--key value` flags, `--switch`es.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (the first is the subcommand).
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

/// Every typed `on|off` flag, declared exactly once. Listing a flag
/// here is what makes it value-taking — [`is_valued`] consults this
/// list — so a toggle cannot be forgotten in the valued registry by
/// construction (the regression test below enumerates this list).
pub const TOGGLE_FLAGS: &[&str] =
    &["adaptive-occupancy", "kv-sessions", "pipelining", "prefix-sharing"];

/// Non-toggle flags that take a value (everything starting with `--`
/// and in neither this list nor [`TOGGLE_FLAGS`] is a switch).
const VALUED: &[&str] = &[
    "mode", "budget", "depth", "topk", "cache-strategy", "cache-layout", "commit-mode",
    "draft-window", "max-new", "workers", "batch",
    "scheduling", "seed",
    "out-dir", "artifacts", "backend", "agree", "temperature", "trace-dir", "prompt-len",
    "turns", "conversations", "profile", "requests", "rate", "servers",
    "slo-ms", "slo-action", "arrivals", "rate-hi", "switch-p",
    "slots", "prompt-mean", "shared-prefix",
];

/// Whether `--name` takes a value (toggles are valued by construction).
fn is_valued(name: &str) -> bool {
    TOGGLE_FLAGS.contains(&name) || VALUED.contains(&name)
}

/// A typed `on|off` flag value.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Toggle {
    /// The feature is enabled.
    On,
    /// The feature is disabled.
    Off,
}

impl Toggle {
    /// Parse a flag's value; anything but `on`/`off` is a typed
    /// [`ConfigError::BadToggle`] naming the flag.
    pub fn parse(flag: &'static str, value: &str) -> Result<Self, ConfigError> {
        match value {
            "on" => Ok(Toggle::On),
            "off" => Ok(Toggle::Off),
            other => Err(ConfigError::BadToggle { flag, got: other.to_string() }),
        }
    }

    /// `on` is `true`.
    pub fn as_bool(self) -> bool {
        matches!(self, Toggle::On)
    }

    /// Stable string form.
    pub fn as_str(self) -> &'static str {
        match self {
            Toggle::On => "on",
            Toggle::Off => "off",
        }
    }
}

impl Args {
    /// Parse an argv iterator (without the program name).
    pub fn parse(argv: impl Iterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        while let Some(a) = argv.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if is_valued(name) {
                    match argv.next() {
                        Some(v) if !v.starts_with("--") => {
                            out.flags.insert(name.to_string(), v);
                        }
                        _ => bail!("flag --{name} requires a value"),
                    }
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Raw value of a `--key value` flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Flag value parsed as usize (error on malformed input).
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")))
            .transpose()
    }

    /// Flag value parsed as u64 (error on malformed input).
    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")))
            .transpose()
    }

    /// Flag value parsed as f64 (error on malformed input).
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")))
            .transpose()
    }

    /// Typed value of an `on|off` flag from [`TOGGLE_FLAGS`]: `None`
    /// when absent, [`ConfigError::BadToggle`] when the value is
    /// anything else.
    pub fn get_toggle(&self, flag: &'static str) -> Result<Option<Toggle>> {
        debug_assert!(
            TOGGLE_FLAGS.contains(&flag),
            "--{flag} is not declared in TOGGLE_FLAGS"
        );
        Ok(self.get(flag).map(|v| Toggle::parse(flag, v)).transpose()?)
    }

    /// Whether a boolean `--switch` was passed.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Reject unknown switches/flags for a command.
    pub fn ensure_known(&self, switches: &[&str], flags: &[&str]) -> Result<()> {
        for s in &self.switches {
            if !switches.contains(&s.as_str()) {
                bail!("unknown switch --{s}");
            }
        }
        for k in self.flags.keys() {
            if !flags.contains(&k.as_str()) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn positional_flags_switches() {
        let a = parse("bench-e1 --mode eager --budget 32 --quick");
        assert_eq!(a.positional, vec!["bench-e1"]);
        assert_eq!(a.get("mode"), Some("eager"));
        assert_eq!(a.get_usize("budget").unwrap(), Some(32));
        assert!(a.has("quick"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("serve --max-new=64 --seed=7");
        assert_eq!(a.get_usize("max-new").unwrap(), Some(64));
        assert_eq!(a.get_u64("seed").unwrap(), Some(7));
    }

    #[test]
    fn missing_value_fails() {
        assert!(Args::parse(["x".into(), "--mode".into()].into_iter()).is_err());
    }

    #[test]
    fn ensure_known_rejects_typos() {
        let a = parse("cmd --quick --mode fused");
        assert!(a.ensure_known(&["quick"], &["mode"]).is_ok());
        assert!(a.ensure_known(&[], &["mode"]).is_err());
        assert!(a.ensure_known(&["quick"], &[]).is_err());
    }

    #[test]
    fn bad_number_reported() {
        let a = parse("cmd --budget abc");
        assert!(a.get_usize("budget").is_err());
    }

    #[test]
    fn space_separated_value_flags_are_valued_not_switches() {
        // regression: a VALUED omission silently turns `--flag value` into
        // a switch plus a stray positional
        let a = parse(
            "serve --pipelining off --prefix-sharing on --slo-ms 40 \
             --arrivals bursty --switch-p 0.3",
        );
        assert_eq!(a.get("pipelining"), Some("off"));
        assert_eq!(a.get("prefix-sharing"), Some("on"));
        assert_eq!(a.get_f64("slo-ms").unwrap(), Some(40.0));
        assert_eq!(a.get("arrivals"), Some("bursty"));
        assert_eq!(a.get_f64("switch-p").unwrap(), Some(0.3));
        assert_eq!(a.positional, vec!["serve"]);
    }

    #[test]
    fn negative_values_are_consumed_by_valued_flags() {
        let a = parse("cmd --slo-ms -5");
        assert_eq!(a.get_f64("slo-ms").unwrap(), Some(-5.0));
    }

    #[test]
    fn every_toggle_flag_is_valued_and_typed() {
        // Enumerates TOGGLE_FLAGS: each flag must consume its value (not
        // degrade into a switch + stray positional), parse on/off into a
        // typed Toggle, and reject anything else with a typed
        // ConfigError naming the flag.
        for &flag in TOGGLE_FLAGS {
            let a = parse(&format!("cmd --{flag} on"));
            assert_eq!(a.positional, vec!["cmd"], "--{flag} must consume its value");
            assert_eq!(a.get_toggle(flag).unwrap(), Some(Toggle::On));
            assert!(a.get_toggle(flag).unwrap().unwrap().as_bool());

            let a = parse(&format!("cmd --{flag} off"));
            assert_eq!(a.get_toggle(flag).unwrap(), Some(Toggle::Off));
            assert!(!a.get_toggle(flag).unwrap().unwrap().as_bool());

            assert_eq!(parse("cmd").get_toggle(flag).unwrap(), None);

            let err = parse(&format!("cmd --{flag} maybe")).get_toggle(flag).unwrap_err();
            assert_eq!(
                err.downcast_ref::<ConfigError>(),
                Some(&ConfigError::BadToggle { flag, got: "maybe".to_string() }),
                "--{flag} must reject non on|off values with the typed error"
            );
            let msg = err.to_string();
            assert!(
                msg.contains(&format!("--{flag}")) && msg.contains("on|off"),
                "--{flag} rejection must name the flag and the domain: {msg}"
            );
        }
    }
}
