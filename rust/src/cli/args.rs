//! Minimal argument parser: positional args, `--key value` flags and
//! `--switch` booleans. Unknown-flag detection is done per-command via
//! [`Args::ensure_known`] so typos fail fast instead of being ignored.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Parsed command line: positionals, `--key value` flags, `--switch`es.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (the first is the subcommand).
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

/// Flags that take a value (everything else starting with `--` is a switch).
const VALUED: &[&str] = &[
    "mode", "budget", "depth", "topk", "cache-strategy", "cache-layout", "commit-mode",
    "kv-sessions", "pipelining", "prefix-sharing", "draft-window", "max-new", "workers", "batch",
    "scheduling", "seed",
    "out-dir", "artifacts", "backend", "agree", "temperature", "trace-dir", "prompt-len",
    "turns", "conversations", "profile", "requests", "rate", "servers",
    "adaptive-occupancy", "slo-ms", "slo-action", "arrivals", "rate-hi", "switch-p",
    "slots", "prompt-mean", "shared-prefix",
];

impl Args {
    /// Parse an argv iterator (without the program name).
    pub fn parse(argv: impl Iterator<Item = String>) -> Result<Self> {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        while let Some(a) = argv.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if VALUED.contains(&name) {
                    match argv.next() {
                        Some(v) if !v.starts_with("--") => {
                            out.flags.insert(name.to_string(), v);
                        }
                        _ => bail!("flag --{name} requires a value"),
                    }
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Raw value of a `--key value` flag.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    /// Flag value parsed as usize (error on malformed input).
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")))
            .transpose()
    }

    /// Flag value parsed as u64 (error on malformed input).
    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")))
            .transpose()
    }

    /// Flag value parsed as f64 (error on malformed input).
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.get(key)
            .map(|v| v.parse().map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")))
            .transpose()
    }

    /// Whether a boolean `--switch` was passed.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Reject unknown switches/flags for a command.
    pub fn ensure_known(&self, switches: &[&str], flags: &[&str]) -> Result<()> {
        for s in &self.switches {
            if !switches.contains(&s.as_str()) {
                bail!("unknown switch --{s}");
            }
        }
        for k in self.flags.keys() {
            if !flags.contains(&k.as_str()) {
                bail!("unknown flag --{k}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn positional_flags_switches() {
        let a = parse("bench-e1 --mode eager --budget 32 --quick");
        assert_eq!(a.positional, vec!["bench-e1"]);
        assert_eq!(a.get("mode"), Some("eager"));
        assert_eq!(a.get_usize("budget").unwrap(), Some(32));
        assert!(a.has("quick"));
        assert!(!a.has("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let a = parse("serve --max-new=64 --seed=7");
        assert_eq!(a.get_usize("max-new").unwrap(), Some(64));
        assert_eq!(a.get_u64("seed").unwrap(), Some(7));
    }

    #[test]
    fn missing_value_fails() {
        assert!(Args::parse(["x".into(), "--mode".into()].into_iter()).is_err());
    }

    #[test]
    fn ensure_known_rejects_typos() {
        let a = parse("cmd --quick --mode fused");
        assert!(a.ensure_known(&["quick"], &["mode"]).is_ok());
        assert!(a.ensure_known(&[], &["mode"]).is_err());
        assert!(a.ensure_known(&["quick"], &[]).is_err());
    }

    #[test]
    fn bad_number_reported() {
        let a = parse("cmd --budget abc");
        assert!(a.get_usize("budget").is_err());
    }

    #[test]
    fn space_separated_value_flags_are_valued_not_switches() {
        // regression: a VALUED omission silently turns `--flag value` into
        // a switch plus a stray positional
        let a = parse(
            "serve --pipelining off --prefix-sharing on --slo-ms 40 \
             --arrivals bursty --switch-p 0.3",
        );
        assert_eq!(a.get("pipelining"), Some("off"));
        assert_eq!(a.get("prefix-sharing"), Some("on"));
        assert_eq!(a.get_f64("slo-ms").unwrap(), Some(40.0));
        assert_eq!(a.get("arrivals"), Some("bursty"));
        assert_eq!(a.get_f64("switch-p").unwrap(), Some(0.3));
        assert_eq!(a.positional, vec!["serve"]);
    }

    #[test]
    fn negative_values_are_consumed_by_valued_flags() {
        let a = parse("cmd --slo-ms -5");
        assert_eq!(a.get_f64("slo-ms").unwrap(), Some(-5.0));
    }
}
