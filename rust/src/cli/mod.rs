//! Command-line interface (hand-rolled flag parser — no clap offline).

pub mod args;
pub mod commands;

pub use args::Args;
pub use commands::main_entry;
