//! Command-line interface (hand-rolled flag parser — no clap offline).

pub mod args;
pub mod commands;

pub use args::{Args, Toggle, TOGGLE_FLAGS};
pub use commands::main_entry;
