//! Subcommand dispatch — the leader entrypoint of the rust coordinator.

use super::args::Args;
use crate::config::{CacheLayout, CacheStrategy, CommitMode, ExecMode, RunConfig};
use crate::coordinator::{
    run_workload, AdmissionPolicy, BackendSpec, CoordinatorConfig, SloAction, SloPolicy,
};
use crate::engine::Engine;
use crate::harness::{replay, run_e1, run_e2, run_e3, run_e4, HarnessConfig, ReplayConfig};
use crate::metrics::{pair_turns, ThroughputReport};
use crate::runtime::golden::{load_goldens, verify_golden};
use crate::runtime::PjrtBackend;
use crate::trace::merge_rank_files;
use crate::workload::{ArrivalKind, Grammar, Profile, PromptFamily, TraceSpec, WorkloadSpec};
use anyhow::{bail, Context, Result};
use std::path::PathBuf;

const USAGE: &str = "eagle-pangu — accelerator-safe tree speculative decoding (EAGLE-Pangu reproduction)

USAGE: eagle-pangu <command> [flags]

COMMANDS
  generate    decode one grammar prompt (EA vs baseline) and print stats
  serve       run the full workload through the multi-worker coordinator
  bench-e1    Table 1 + Fig 1/2a/2b/3 — end-to-end throughput
  bench-e2    Table 2 + Fig 4        — tree budget sweep (code-only)
  bench-e3    Fig 5                  — instrumented stage breakdown
  bench-e4    Table 3 + Fig 6/7      — drafter context truncation
  load        serving-like load evaluation: --requests N --rate R --servers K
  trace-replay  deterministic load replay through the coordinator/worker serving
              split: seeded Poisson or bursty arrivals over mixed grammar prompts,
              consistent-hash sharded across --workers engine workers (typed
              channel RPC), virtual-clock latency p50/p95/p99 + shed rate
              (--arrivals, --rate, --slots, --workers, --turns, --slo-ms)
  goldens     verify rust PJRT execution against python golden fixtures
  traces      merge + report rank trace files: traces <dir>

COMMON FLAGS
  --backend sim|pjrt      model backend (default pjrt when artifacts/ exists)
  --artifacts DIR         artifact directory (default ./artifacts)
  --agree N               sim backend draft/teacher agreement %% (default 85)
  --mode fused|eager      execution path (paper two-mode protocol)
  --budget M --depth D --topk K    tree configuration
  --cache-strategy deepcopy|segment   branch replication (§3.1 ablation)
  --cache-layout flat|paged           physical KV layout: flat full-capacity buffers
                          (default) | block-table paging over a shared per-worker pool
                          (residency follows committed tokens; parked multi-turn
                          conversations keep only their mapped blocks)
  --commit-mode length|path-index     commit mode (§3.1)
  --kv-sessions on|off    device-resident KV sessions (default on): bind each
                          conversation cache on the backend and stream only dirty-row
                          deltas per step instead of re-uploading full caches (fused
                          path only; eager stays full-upload for debuggability)
  --pipelining on|off     software-pipelined serve loop (default on): overlap draft
                          expansion and retire/admit with the previous fused launch
                          still in flight (begin/await half-ticks); off keeps the
                          depth-synchronous reference path — outputs are bit-identical
                          either way, this is a wall-clock A/B axis only
  --prefix-sharing on|off copy-on-write prefix sharing (default off; requires
                          --cache-layout paged): conversations whose prompt prefix
                          matches a resident frozen block run adopt those KV blocks
                          refcounted and skip prefill for the shared run; divergent
                          writes privatize the touched block (copy-on-write);
                          emitted tokens are bit-identical to sharing off
  --no-fast-reorder       disable the prefix-sharing fast reorder
  --unsafe-indexing       skip §3.2 invariant checks (ablation)
  --adaptive              adaptive tree-budget policy (E2 takeaway)
  --adaptive-occupancy on|off  load-adaptive speculation (default off; requires
                          --adaptive): the scheduler feeds live-slot occupancy into
                          the budget controller each tick, shrinking the tree budget
                          as the batch fills at fixed utilization; off is
                          token-bit-identical to the plain adaptive controller
  --slo-ms T              per-request latency SLO in virtual ms (trace-replay):
                          attach a deadline to every replayed request
  --slo-action shed|queue what an expired deadline does (default shed): shed drops
                          the request pre-admission with a typed notice; queue
                          keeps it waiting (deadline is observational)
  --arrivals poisson|bursty  trace-replay arrival process (default poisson); bursty
                          is a 2-state Markov-modulated Poisson (--rate low state,
                          --rate-hi high state, --switch-p per-arrival flip chance)
  --slots B               trace-replay engine slots per worker (serving batch width,
                          default 4)
  --turns T               trace-replay turns per conversation (default 1): above 1,
                          conversations park after each non-final turn and resume
                          with a deterministic follow-up prompt (multi-turn
                          park/resume churn across the channel RPC)
  --prompt-mean N         trace-replay mean prompt length (default 16)
  --shared-prefix N       trace-replay shared-prefix prompt family: every request
                          extends one common N-token system prompt with its own
                          grammar continuation (--prompt-mean becomes the mean
                          suffix length); the workload --prefix-sharing exploits
  --draft-window W        truncate drafter context (E4)
  --max-new N             tokens per turn
  --temperature T         0 = greedy (default)
  --workers N             world size: serve worker threads (default 2), or
                          trace-replay engine workers behind the channel-RPC
                          coordinator (default 1 — workers 1 is bit-identical to
                          single-scheduler replay; any N streams each conversation's
                          tokens identically, only latency shifts)
  --batch B               engine slots (fused launch width) per worker (serve; default 1;
                          0 is rejected — the config contract requires B >= 1)
  --scheduling P          serve group formation: continuous (default; retired conversations
                          free their slot for the next queued one mid-flight) | chunked
                          (PR-2 fixed groups, kept for A/B comparison)
  --seed S  --out-dir DIR  --quick  --verbose  --attention-stats
";

const RUN_FLAGS: &[&str] = &[
    "backend", "artifacts", "agree", "mode", "budget", "depth", "topk",
    "cache-strategy", "cache-layout", "commit-mode", "kv-sessions", "pipelining",
    "prefix-sharing", "draft-window", "max-new",
    "temperature", "workers", "batch", "scheduling", "seed", "out-dir", "trace-dir",
    "prompt-len", "conversations", "profile", "turns", "requests", "rate", "servers",
    "adaptive-occupancy", "slo-ms", "slo-action", "arrivals", "rate-hi", "switch-p",
    "slots", "prompt-mean", "shared-prefix",
];
const RUN_SWITCHES: &[&str] = &[
    "quick", "verbose", "no-fast-reorder", "unsafe-indexing", "attention-stats",
    "instrument", "baseline-only", "ea-only", "adaptive", "help",
];

/// Binary entry point: parse `std::env::args` and dispatch.
pub fn main_entry() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    dispatch(&args)
}

/// Dispatch a parsed command line to its subcommand (prints usage when
/// no command or `--help` is given).
pub fn dispatch(args: &Args) -> Result<()> {
    let Some(cmd) = args.positional.first().map(String::as_str) else {
        println!("{USAGE}");
        return Ok(());
    };
    if args.has("help") {
        println!("{USAGE}");
        return Ok(());
    }
    args.ensure_known(RUN_SWITCHES, RUN_FLAGS)?;
    match cmd {
        "generate" => cmd_generate(args),
        "serve" => cmd_serve(args),
        "bench-e1" => harness(args)?.pipe(|h| run_e1(&h).map(|_| ())),
        "bench-e2" => harness(args)?.pipe(|h| run_e2(&h).map(|_| ())),
        "bench-e3" => harness(args)?.pipe(|h| run_e3(&h).map(|_| ())),
        "bench-e4" => {
            let h = harness(args)?;
            run_e4(&h, args.has("attention-stats")).map(|_| ())
        }
        "load" => cmd_load(args),
        "trace-replay" => cmd_trace_replay(args),
        "goldens" => cmd_goldens(args),
        "traces" => cmd_traces(args),
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

trait Pipe: Sized {
    fn pipe<T>(self, f: impl FnOnce(Self) -> Result<T>) -> Result<T> {
        f(self)
    }
}
impl<T> Pipe for T {}

// ----------------------------------------------------------------------
// Shared flag -> config plumbing
// ----------------------------------------------------------------------

/// Build the [`RunConfig`] from command-line flags (validated).
pub fn run_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    if let Some(m) = args.get("mode") {
        cfg.mode = ExecMode::parse(m)?;
    }
    if let Some(b) = args.get_usize("budget")? {
        cfg.tree.budget = b;
    }
    if let Some(d) = args.get_usize("depth")? {
        cfg.tree.depth_max = d;
    }
    if let Some(k) = args.get_usize("topk")? {
        cfg.tree.topk = k;
    }
    if let Some(s) = args.get("cache-strategy") {
        cfg.cache_strategy = CacheStrategy::parse(s)?;
    }
    if let Some(l) = args.get("cache-layout") {
        cfg.cache_layout = CacheLayout::parse(l)?;
    }
    if let Some(c) = args.get("commit-mode") {
        cfg.commit_mode = CommitMode::parse(c)?;
    }
    if let Some(t) = args.get_toggle("kv-sessions")? {
        cfg.kv_sessions = t.as_bool();
    }
    if let Some(t) = args.get_toggle("pipelining")? {
        cfg.pipelining = t.as_bool();
    }
    if let Some(t) = args.get_toggle("prefix-sharing")? {
        cfg.prefix_sharing = t.as_bool();
    }
    cfg.fast_reorder = !args.has("no-fast-reorder");
    cfg.check_invariants = !args.has("unsafe-indexing");
    if let Some(w) = args.get_usize("draft-window")? {
        cfg.draft_window = Some(w);
    }
    if let Some(n) = args.get_usize("max-new")? {
        cfg.max_new_tokens = n;
    }
    if let Some(t) = args.get_f64("temperature")? {
        cfg.temperature = t;
    }
    if let Some(s) = args.get_u64("seed")? {
        cfg.seed = s;
    }
    cfg.instrument = args.has("instrument");
    cfg.attention_stats = args.has("attention-stats");
    cfg.adaptive_budget = args.has("adaptive");
    if let Some(t) = args.get_toggle("adaptive-occupancy")? {
        cfg.adaptive_occupancy = t.as_bool();
    }
    cfg.validate()?;
    Ok(cfg)
}

/// Select the backend from flags: explicit `--backend`, else PJRT when
/// artifacts exist, else the simulator.
pub fn backend_spec(args: &Args) -> Result<BackendSpec> {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    match args.get("backend") {
        Some("sim") => Ok(BackendSpec::Sim {
            agree_pct: args.get_u64("agree")?.unwrap_or(85),
        }),
        Some("pjrt") | None if artifacts.join("manifest.json").exists() => {
            Ok(BackendSpec::Pjrt { artifact_dir: artifacts })
        }
        Some("pjrt") => bail!("--backend pjrt but {artifacts:?} has no manifest.json — run `make artifacts`"),
        None => {
            eprintln!("note: no artifacts found, falling back to the sim backend");
            Ok(BackendSpec::Sim { agree_pct: args.get_u64("agree")?.unwrap_or(85) })
        }
        Some(other) => bail!("unknown backend '{other}'"),
    }
}

fn harness(args: &Args) -> Result<HarnessConfig> {
    Ok(HarnessConfig {
        backend: backend_spec(args)?,
        out_dir: PathBuf::from(args.get("out-dir").unwrap_or("results")),
        world_size: args.get_usize("workers")?.unwrap_or(2),
        run: run_config(args)?,
        quick: args.has("quick"),
        verbose: args.has("verbose"),
    })
}

// ----------------------------------------------------------------------
// Commands
// ----------------------------------------------------------------------

fn cmd_generate(args: &Args) -> Result<()> {
    let cfg = run_config(args)?;
    let prompt_len = args.get_usize("prompt-len")?.unwrap_or(48);
    let profile = args
        .get("profile")
        .map(|p| Profile::parse(p).context(format!("bad profile '{p}'")))
        .transpose()?
        .unwrap_or(Profile::Code);
    let prompt = Grammar::new(profile).sample_sequence(prompt_len, cfg.seed, None);
    let spec = backend_spec(args)?;
    println!("backend: {} | mode: {} | prompt: {} tokens ({})",
             spec.describe(), cfg.mode.as_str(), prompt.len(), profile.as_str());

    let mut b_ea = spec.build_boxed()?;
    let mut e_ea = Engine::new(&*b_ea, cfg.clone());
    e_ea.warmup(&mut *b_ea)?;
    let ea = e_ea.generate_speculative(&mut *b_ea, &prompt, cfg.max_new_tokens)?;

    let mut b_base = spec.build_boxed()?;
    let mut e_base = Engine::new(&*b_base, cfg.clone());
    e_base.warmup(&mut *b_base)?;
    let base = e_base.generate_baseline(&mut *b_base, &prompt, ea.tokens.len())?;

    anyhow::ensure!(ea.tokens == base.tokens,
                    "EA output diverged from teacher-greedy — decoding bug");
    println!("output ({} tokens, identical EA vs baseline): {:?}...",
             ea.tokens.len(), &ea.tokens[..ea.tokens.len().min(16)]);
    println!("  baseline: {:>8.2} tok/s  ({} teacher calls)",
             base.tok_per_sec(), base.teacher_calls);
    println!("  EA:       {:>8.2} tok/s  ({} teacher calls, {} draft calls, accept_L mean {:.2})",
             ea.tok_per_sec(), ea.teacher_calls, ea.draft_calls, ea.mean_accept_len());
    println!("  speedup:  {:>8.2}x", ea.tok_per_sec() / base.tok_per_sec().max(1e-9));
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let run = run_config(args)?;
    let mut workload = if args.has("quick") {
        WorkloadSpec::smoke()
    } else {
        WorkloadSpec::default()
    };
    if let Some(n) = args.get_usize("conversations")? {
        workload.code_conversations = n / 2;
        workload.chat_conversations = n - n / 2;
    }
    workload.seed = run.seed;
    let cfg = CoordinatorConfig {
        world_size: args.get_usize("workers")?.unwrap_or(2),
        run,
        workload,
        backend: backend_spec(args)?,
        trace_dir: PathBuf::from(args.get("trace-dir").unwrap_or("results/serve")),
        run_baseline: !args.has("ea-only"),
        run_ea: !args.has("baseline-only"),
        max_batch: args.get_usize("batch")?.unwrap_or(1),
        scheduling: args
            .get("scheduling")
            .map(AdmissionPolicy::parse)
            .transpose()?
            .unwrap_or(AdmissionPolicy::Continuous),
        verbose: args.has("verbose") || !args.has("quick"),
    };
    let records = run_workload(&cfg)?;
    let pairs = pair_turns(&records);
    if !pairs.is_empty() {
        println!("{}", ThroughputReport::from_pairs(&pairs).table1());
    } else {
        println!("{} turn records written to {}", records.len(), cfg.trace_dir.display());
    }
    Ok(())
}

fn cmd_load(args: &Args) -> Result<()> {
    use crate::coordinator::{run_load, LoadSpec};
    let run = run_config(args)?;
    let mut spec = LoadSpec::default();
    if let Some(n) = args.get_usize("requests")? {
        spec.requests = n;
    }
    if let Some(r) = args.get_f64("rate")? {
        spec.arrival_rate = r;
    }
    if let Some(s) = args.get_usize("servers")? {
        spec.servers = s;
    }
    if let Some(p) = args.get_usize("prompt-len")? {
        spec.prompt_len = p;
    }
    spec.max_new = run.max_new_tokens.min(96);
    spec.seed = run.seed;
    let report = run_load(&backend_spec(args)?, &run, &spec)?;
    println!("{}", report.render());
    Ok(())
}

/// Build the SLO policy from `--slo-ms` / `--slo-action` (None when no
/// deadline is requested; `--slo-action` without `--slo-ms` is a
/// contract error so a typo can't silently drop the deadline).
fn slo_from_args(args: &Args) -> Result<Option<SloPolicy>> {
    let Some(target_ms) = args.get_f64("slo-ms")? else {
        if args.get("slo-action").is_some() {
            return Err(crate::config::ConfigError::SloActionWithoutDeadline.into());
        }
        return Ok(None);
    };
    let action = args
        .get("slo-action")
        .map(SloAction::parse)
        .transpose()?
        .unwrap_or(SloAction::Shed);
    let policy = SloPolicy { target_ms, action };
    policy.validate()?;
    Ok(Some(policy))
}

fn cmd_trace_replay(args: &Args) -> Result<()> {
    let run = run_config(args)?;
    let rate = args.get_f64("rate")?.unwrap_or(40.0);
    let kind = match args.get("arrivals").unwrap_or("poisson") {
        "poisson" => ArrivalKind::Poisson { rate_rps: rate },
        "bursty" => ArrivalKind::Bursty {
            rate_lo_rps: rate,
            rate_hi_rps: args.get_f64("rate-hi")?.unwrap_or(rate * 8.0),
            switch_p: args.get_f64("switch-p")?.unwrap_or(0.25),
        },
        other => bail!("unknown --arrivals value '{other}' (expected poisson|bursty)"),
    };
    let family = match args.get_usize("shared-prefix")? {
        Some(prefix_len) => PromptFamily::SharedPrefix { prefix_len },
        None => PromptFamily::Mixed,
    };
    let spec = TraceSpec {
        requests: args.get_usize("requests")?.unwrap_or(48),
        kind,
        family,
        prompt_mean: args.get_usize("prompt-mean")?.unwrap_or(16),
        max_new: args.get_usize("max-new")?.unwrap_or(6),
        seed: run.seed,
    };
    let mut cfg = ReplayConfig::new(args.get_usize("slots")?.unwrap_or(4));
    cfg.workers = args.get_usize("workers")?.unwrap_or(1);
    cfg.turns = args.get_usize("turns")?.unwrap_or(1);
    cfg.agree_pct = args.get_u64("agree")?.unwrap_or(90);
    cfg.slo = slo_from_args(args)?;
    cfg.run = run;
    cfg.validate()?;
    let trace = spec.generate()?;
    let report = replay(&trace, &cfg)?;
    let slo_desc = match cfg.slo {
        Some(p) => format!("{:.1} ms / {}", p.target_ms, p.action.as_str()),
        None => "none".to_string(),
    };
    println!(
        "trace-replay: {} requests, {} workers x {} slots, {} turn(s), pipelining {}, SLO {}",
        report.total,
        cfg.workers,
        cfg.slots,
        cfg.turns,
        if cfg.run.pipelining { "on" } else { "off" },
        slo_desc,
    );
    println!(
        "  completed {}  shed {}  (shed rate {:.1}%)",
        report.completed,
        report.shed,
        report.shed_rate * 100.0
    );
    println!(
        "  latency (virtual ms): mean {:.2}  p50 {:.2}  p95 {:.2}  p99 {:.2}",
        report.mean_ms, report.p50_ms, report.p95_ms, report.p99_ms
    );
    Ok(())
}

fn cmd_goldens(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let mut backend = PjrtBackend::load(&dir)?;
    let goldens = load_goldens(&dir)?;
    for rec in &goldens {
        verify_golden(&mut backend, rec)?;
        println!("golden OK: {}", rec.module);
    }
    println!("{} golden fixtures verified against python outputs", goldens.len());
    Ok(())
}

fn cmd_traces(args: &Args) -> Result<()> {
    let dir = args
        .positional
        .get(1)
        .map(PathBuf::from)
        .or_else(|| args.get("trace-dir").map(PathBuf::from))
        .context("usage: traces <dir>")?;
    let records = merge_rank_files(&dir)?;
    println!("merged {} records -> {}", records.len(),
             dir.join("trace_merged.jsonl").display());
    let pairs = pair_turns(&records);
    if !pairs.is_empty() {
        println!("{}", ThroughputReport::from_pairs(&pairs).table1());
    }
    Ok(())
}

impl BackendSpec {
    /// Boxed build for single-engine commands.
    pub fn build_boxed(&self) -> Result<Box<dyn crate::backend::ModelBackend>> {
        match self {
            BackendSpec::Sim { agree_pct } => {
                Ok(Box::new(crate::backend::sim::SimBackend::new(*agree_pct)))
            }
            BackendSpec::Pjrt { artifact_dir } => Ok(Box::new(PjrtBackend::load(artifact_dir)?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn run_config_from_flags() {
        let a = parse("serve --mode eager --budget 32 --depth 6 --cache-strategy deepcopy \
                       --cache-layout paged --commit-mode length --no-fast-reorder \
                       --draft-window 64 --max-new 10 --seed 3 --unsafe-indexing");
        let c = run_config(&a).unwrap();
        assert_eq!(c.mode, ExecMode::Eager);
        assert_eq!(c.tree.budget, 32);
        assert_eq!(c.tree.depth_max, 6);
        assert_eq!(c.cache_strategy, CacheStrategy::DeepCopy);
        assert_eq!(c.cache_layout, CacheLayout::Paged);
        assert_eq!(c.commit_mode, CommitMode::Length);
        assert!(!c.fast_reorder);
        assert!(!c.check_invariants);
        assert_eq!(c.draft_window, Some(64));
        assert_eq!(c.max_new_tokens, 10);
        assert_eq!(c.seed, 3);
    }

    #[test]
    fn sim_backend_selected_explicitly() {
        let a = parse("serve --backend sim --agree 70");
        match backend_spec(&a).unwrap() {
            BackendSpec::Sim { agree_pct } => assert_eq!(agree_pct, 70),
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn unknown_command_errors() {
        let a = parse("frobnicate");
        assert!(dispatch(&a).is_err());
    }

    #[test]
    fn generate_on_sim_backend_works_end_to_end() {
        let a = parse("generate --backend sim --agree 90 --max-new 12 --prompt-len 16 --quick");
        dispatch(&a).unwrap();
    }

    #[test]
    fn invalid_flag_combinations_fail() {
        assert!(run_config(&parse("serve --budget 0")).is_err());
        assert!(run_config(&parse("serve --mode turbo")).is_err());
        assert!(run_config(&parse("serve --cache-layout sparse")).is_err());
        assert!(run_config(&parse("serve --kv-sessions maybe")).is_err());
        assert!(run_config(&parse("serve --pipelining maybe")).is_err());
        assert!(backend_spec(&parse("serve --backend quantum")).is_err());
    }

    #[test]
    fn prefix_sharing_flag_parses_and_requires_paged_layout() {
        assert!(
            !run_config(&parse("serve")).unwrap().prefix_sharing,
            "prefix sharing defaults off"
        );
        let c = run_config(&parse("serve --cache-layout paged --prefix-sharing on")).unwrap();
        assert!(c.prefix_sharing);
        assert!(
            !run_config(&parse("serve --cache-layout paged --prefix-sharing off"))
                .unwrap()
                .prefix_sharing
        );
        let err = run_config(&parse("serve --prefix-sharing on")).unwrap_err();
        assert!(
            format!("{err:#}").contains("--prefix-sharing"),
            "error must name the flag: {err:#}"
        );
        assert!(run_config(&parse("serve --cache-layout paged --prefix-sharing maybe")).is_err());
    }

    #[test]
    fn pipelining_flag_parses() {
        assert!(run_config(&parse("serve")).unwrap().pipelining, "pipelining default on");
        assert!(!run_config(&parse("serve --pipelining off")).unwrap().pipelining);
        assert!(run_config(&parse("serve --pipelining on")).unwrap().pipelining);
    }

    #[test]
    fn kv_sessions_flag_parses() {
        assert!(run_config(&parse("serve")).unwrap().kv_sessions, "sessions default on");
        assert!(!run_config(&parse("serve --kv-sessions off")).unwrap().kv_sessions);
        assert!(run_config(&parse("serve --kv-sessions on")).unwrap().kv_sessions);
    }

    #[test]
    fn generate_on_paged_layout_works_end_to_end() {
        let a = parse(
            "generate --backend sim --agree 90 --max-new 12 --prompt-len 16 \
             --cache-layout paged --quick",
        );
        dispatch(&a).unwrap();
    }

    #[test]
    fn serve_rejects_zero_batch_with_contract_error() {
        // --batch 0 must fail loudly instead of silently degenerating to
        // sequential serving (and must not touch the trace directory).
        let a = parse("serve --backend sim --quick --batch 0 --max-new 4");
        let err = dispatch(&a).unwrap_err();
        assert!(
            format!("{err:#}").contains("max_batch"),
            "error must name the config contract: {err:#}"
        );
    }

    #[test]
    fn adaptive_occupancy_flag_parses_and_requires_adaptive() {
        assert!(
            !run_config(&parse("serve")).unwrap().adaptive_occupancy,
            "occupancy mode defaults off"
        );
        let c = run_config(&parse("serve --adaptive --adaptive-occupancy on")).unwrap();
        assert!(c.adaptive_budget && c.adaptive_occupancy);
        assert!(
            !run_config(&parse("serve --adaptive --adaptive-occupancy off"))
                .unwrap()
                .adaptive_occupancy
        );
        let err = run_config(&parse("serve --adaptive-occupancy on")).unwrap_err();
        assert!(
            format!("{err:#}").contains("--adaptive-occupancy"),
            "error must name the flag: {err:#}"
        );
        assert!(run_config(&parse("serve --adaptive --adaptive-occupancy maybe")).is_err());
    }

    #[test]
    fn trace_replay_smoke_runs_on_sim() {
        let a = parse("trace-replay --requests 8 --rate 50 --slots 2 --max-new 4 --seed 7");
        dispatch(&a).unwrap();
        // multi-worker + multi-turn park/resume over the channel RPC
        let a = parse(
            "trace-replay --requests 8 --rate 50 --slots 2 --workers 3 --turns 2 \
             --max-new 4 --seed 7",
        );
        dispatch(&a).unwrap();
        let a = parse(
            "trace-replay --requests 8 --arrivals bursty --rate 20 --rate-hi 200 \
             --switch-p 0.3 --slots 2 --max-new 4 --pipelining off \
             --slo-ms 40 --slo-action shed --seed 7",
        );
        dispatch(&a).unwrap();
        let a = parse(
            "trace-replay --requests 6 --rate 50 --slots 2 --max-new 4 \
             --shared-prefix 24 --cache-layout paged --prefix-sharing on --seed 7",
        );
        dispatch(&a).unwrap();
    }

    #[test]
    fn trace_replay_rejects_degenerate_configs_by_flag_name() {
        for (cli, flag) in [
            ("trace-replay --slo-ms 0", "--slo-ms"),
            ("trace-replay --slo-ms -5", "--slo-ms"),
            ("trace-replay --requests 0", "--requests"),
            ("trace-replay --rate 0", "--rate"),
            ("trace-replay --slots 0", "--slots"),
            ("trace-replay --arrivals bursty --rate 50 --rate-hi 10", "--rate-hi"),
            ("trace-replay --arrivals bursty --switch-p 0", "--switch-p"),
            ("trace-replay --slo-action shed", "--slo-action"),
            ("trace-replay --shared-prefix 4", "--shared-prefix"),
            ("trace-replay --workers 0", "--workers"),
            ("trace-replay --turns 0", "--turns"),
        ] {
            let err = dispatch(&parse(cli)).unwrap_err();
            assert!(
                format!("{err:#}").contains(flag),
                "`{cli}` must fail naming {flag}: {err:#}"
            );
        }
        assert!(dispatch(&parse("trace-replay --arrivals chaotic")).is_err());
        assert!(dispatch(&parse("trace-replay --slo-ms 40 --slo-action drop")).is_err());
    }

    #[test]
    fn contract_errors_are_typed_variants() {
        use crate::config::ConfigError;
        let err = run_config(&parse("serve --prefix-sharing on")).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ConfigError>(),
            Some(&ConfigError::PrefixSharingRequiresPaged)
        );
        let err = run_config(&parse("serve --adaptive-occupancy on")).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ConfigError>(),
            Some(&ConfigError::OccupancyRequiresAdaptive)
        );
        let err = run_config(&parse("serve --pipelining maybe")).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ConfigError>(),
            Some(&ConfigError::BadToggle { flag: "pipelining", got: "maybe".to_string() })
        );
        let err = dispatch(&parse("trace-replay --slo-action shed")).unwrap_err();
        assert_eq!(
            err.downcast_ref::<ConfigError>(),
            Some(&ConfigError::SloActionWithoutDeadline)
        );
    }

    #[test]
    fn scheduling_flag_parses_and_rejects_unknown() {
        assert_eq!(
            AdmissionPolicy::parse("continuous").unwrap(),
            AdmissionPolicy::Continuous
        );
        assert_eq!(AdmissionPolicy::parse("chunked").unwrap(), AdmissionPolicy::Chunked);
        assert!(AdmissionPolicy::parse("warp").is_err());
        let a = parse("serve --backend sim --quick --scheduling warp");
        assert!(dispatch(&a).is_err());
    }
}
