//! Golden-fixture verification: regenerate the procedural inputs that
//! `python/compile/aot.py` used (same splitmix64 stream, bit-for-bit) and
//! compare the rust-side PJRT execution against the python-recorded
//! outputs. This is the cross-language integration signal: if literal
//! layout, input ordering, mask convention or the HLO round-trip drifts,
//! these checks fail loudly.

use crate::backend::{KvView, ModelBackend, StepArgs, StepScratch};
use crate::config::contract::NEG_INF;
use crate::config::{Contract, ExecMode};
use crate::json::Json;
use crate::util::SplitMix64;
use anyhow::{bail, Context, Result};

/// Block size of the golden fixtures.
pub const GOLDEN_S: usize = 8;
/// Committed-prefix length of the golden fixtures.
pub const GOLDEN_PREFIX: usize = 16;
/// Seed of the golden input stream (shared with `aot.py`).
pub const GOLDEN_SEED: u64 = 0x5EED;

/// Procedurally generated golden inputs (parity with `aot.py::golden_inputs`).
pub struct GoldenInputs {
    /// `[GOLDEN_S]` token ids.
    pub tokens: Vec<i32>,
    /// `[GOLDEN_S, F]` feature rows (draft role only).
    pub feats: Option<Vec<f32>>,
    /// `[GOLDEN_S]` RoPE positions.
    pub positions: Vec<i32>,
    /// `[GOLDEN_S, cap + GOLDEN_S]` prefix-plus-causal mask.
    pub mask: Vec<f32>,
    /// Random-filled key cache.
    pub k_cache: Vec<f32>,
    /// Random-filled value cache.
    pub v_cache: Vec<f32>,
}

/// Regenerate the golden inputs for `role` (`teacher` | `draft`),
/// bit-for-bit identical to the python generator.
pub fn golden_inputs(contract: &Contract, role: &str) -> GoldenInputs {
    let mut st = SplitMix64::new(GOLDEN_SEED);
    let (s, t) = (GOLDEN_S, GOLDEN_PREFIX);
    let d = if role == "teacher" { contract.teacher } else { contract.draft };
    let cap = contract.cache_cap;
    let tokens: Vec<i32> =
        (0..s).map(|_| 2 + (st.next_u64() % (contract.vocab as u64 - 2)) as i32).collect();
    let n = d.cache_elems(cap);
    let k_cache: Vec<f32> = (0..n).map(|_| st.f32_pm1()).collect();
    let v_cache: Vec<f32> = (0..n).map(|_| st.f32_pm1()).collect();
    let feats = if role == "draft" {
        Some((0..s * contract.feat_dim).map(|_| st.f32_pm1()).collect())
    } else {
        None
    };
    let positions: Vec<i32> = (0..s).map(|i| (t + i) as i32).collect();
    let w = cap + s;
    let mut mask = vec![NEG_INF; s * w];
    for i in 0..s {
        mask[i * w..i * w + t].fill(0.0);
        for j in 0..=i {
            mask[i * w + cap + j] = 0.0;
        }
    }
    GoldenInputs { tokens, feats, positions, mask, k_cache, v_cache }
}

/// One golden record from artifacts/golden.json.
#[derive(Debug)]
pub struct GoldenRecord {
    /// Artifact module name (e.g. `teacher_fused_s8`).
    pub module: String,
    /// First logits values recorded by python.
    pub logits_sample: Vec<f64>,
    /// Sum over all logits.
    pub logits_sum: f64,
    /// Argmax of row 0 (greedy-equivalence check).
    pub logits_argmax_row0: usize,
    /// Sum over the feature block.
    pub feats_sum: f64,
    /// Sum over the new K rows.
    pub k_new_sum: f64,
}

/// Parse `golden.json` from an artifact directory.
pub fn load_goldens(dir: &std::path::Path) -> Result<Vec<GoldenRecord>> {
    let text = std::fs::read_to_string(dir.join("golden.json")).context("reading golden.json")?;
    let v = crate::json::parse(&text).map_err(|e| anyhow::anyhow!("golden.json: {e}"))?;
    let arr = v.as_arr().context("golden.json not an array")?;
    arr.iter()
        .map(|g| {
            Ok(GoldenRecord {
                module: g.get("module").and_then(Json::as_str).context("module")?.to_string(),
                logits_sample: g
                    .get("logits_sample")
                    .and_then(Json::as_arr)
                    .context("logits_sample")?
                    .iter()
                    .filter_map(Json::as_f64)
                    .collect(),
                logits_sum: g.get("logits_sum").and_then(Json::as_f64).context("logits_sum")?,
                logits_argmax_row0: g
                    .get("logits_argmax_row0")
                    .and_then(Json::as_usize)
                    .context("argmax")?,
                feats_sum: g.get("feats_sum").and_then(Json::as_f64).context("feats_sum")?,
                k_new_sum: g.get("k_new_sum").and_then(Json::as_f64).context("k_new_sum")?,
            })
        })
        .collect()
}

/// Run one golden record against the backend; error on mismatch.
pub fn verify_golden(backend: &mut dyn ModelBackend, rec: &GoldenRecord) -> Result<()> {
    let contract = backend.contract().clone();
    let (role, mode) = match rec.module.as_str() {
        "teacher_fused_s8" => ("teacher", ExecMode::Fused),
        "teacher_eager_s8" => ("teacher", ExecMode::Eager),
        "draft_s8" => ("draft", ExecMode::Fused),
        other => bail!("unknown golden module {other}"),
    };
    let gi = golden_inputs(&contract, role);
    let args = StepArgs {
        tokens: &gi.tokens,
        positions: &gi.positions,
        mask: &gi.mask,
        kv: KvView::flat(&gi.k_cache, &gi.v_cache, contract.cache_cap),
        feats_in: gi.feats.as_deref(),
        probe: false,
        session: None,
    };
    let mut out = StepScratch::new();
    if role == "teacher" {
        backend.teacher_step(mode, args, &mut out)?;
    } else {
        backend.draft_step(args, &mut out)?;
    }
    let close = |a: f64, b: f64, tol: f64, what: &str| -> Result<()> {
        // relative-ish tolerance: sums accumulate over thousands of f32 ops
        if (a - b).abs() > tol * (1.0 + b.abs()) {
            bail!("{}: {what} mismatch: rust {a} vs python {b}", rec.module);
        }
        Ok(())
    };
    for (i, expect) in rec.logits_sample.iter().enumerate() {
        close(out.logits[i] as f64, *expect, 2e-4, &format!("logits_sample[{i}]"))?;
    }
    let lsum: f64 = out.logits.iter().map(|x| *x as f64).sum();
    close(lsum, rec.logits_sum, 1e-3, "logits_sum")?;
    let fsum: f64 = out.feats.iter().map(|x| *x as f64).sum();
    close(fsum, rec.feats_sum, 1e-3, "feats_sum")?;
    let ksum: f64 = out.k_new.iter().map(|x| *x as f64).sum();
    close(ksum, rec.k_new_sum, 1e-3, "k_new_sum")?;
    let am = crate::backend::argmax(out.logits_row(0));
    if am != rec.logits_argmax_row0 {
        bail!("{}: argmax row0 {am} vs python {}", rec.module, rec.logits_argmax_row0);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_inputs_are_deterministic_and_shaped() {
        let c = Contract::default();
        let a = golden_inputs(&c, "teacher");
        let b = golden_inputs(&c, "teacher");
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.k_cache.len(), c.teacher.cache_elems(c.cache_cap));
        assert_eq!(a.mask.len(), GOLDEN_S * (c.cache_cap + GOLDEN_S));
        assert!(a.feats.is_none());
        let d = golden_inputs(&c, "draft");
        assert_eq!(d.feats.as_ref().unwrap().len(), GOLDEN_S * c.feat_dim);
        assert!(a.tokens.iter().all(|t| (2..512).contains(t)));
    }

    #[test]
    fn mask_is_prefix_plus_causal() {
        let c = Contract::default();
        let g = golden_inputs(&c, "teacher");
        let w = c.cache_cap + GOLDEN_S;
        assert_eq!(g.mask[0], 0.0);
        assert_eq!(g.mask[GOLDEN_PREFIX], NEG_INF);
        assert_eq!(g.mask[c.cache_cap], 0.0); // self
        assert_eq!(g.mask[c.cache_cap + 1], NEG_INF);
        assert_eq!(g.mask[w + c.cache_cap + 1], 0.0); // row 1 sees slot 1
    }
}
