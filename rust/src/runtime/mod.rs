//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) through
//! the `xla` crate's PJRT CPU client and exposes them as a
//! [`crate::backend::ModelBackend`].
//!
//! HLO **text** is the interchange format: jax >= 0.5 emits protos with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `python/compile/aot.py`). Executables are
//! compiled lazily per (role, mode, S) variant and cached for the process
//! lifetime. PJRT handles are !Send — each coordinator worker owns its own
//! backend instance.

pub mod golden;
pub mod pjrt;

pub use pjrt::PjrtBackend;
