//! The production backend: artifact registry + PJRT execution.

use crate::backend::{KvIndex, KvView, ModelBackend, StepArgs, StepScratch};
use crate::config::{Contract, Dims, ExecMode};
use crate::json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Statistics about artifact loading / execution (surfaced in manifests
/// and the §Perf logs).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// Modules compiled (lazily, on first use).
    pub compiles: u64,
    /// Total compile wall time, seconds.
    pub compile_secs: f64,
    /// Module executions.
    pub executions: u64,
    /// Total execution wall time, seconds.
    pub execute_secs: f64,
    /// Host->device bytes shipped as literals (per-call tensors).
    pub upload_bytes: u64,
}

/// The production [`ModelBackend`]: AOT HLO artifacts executed through
/// the PJRT CPU client. Fused batched verification currently uses the
/// trait's sequential fallback (true `[B, S]` modules are a compile-side
/// follow-up).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    contract: Contract,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Compile/execute/upload counters (surfaced in manifests).
    pub stats: RuntimeStats,
    /// Probe-capable draft variants present in the artifact set.
    probe_variants: Vec<usize>,
    /// Persistent host staging for paged cache views: the AOT modules
    /// take a contiguous `[L, cap, H, Dh]` cache input, so a block-table
    /// view is gathered into these buffers before upload (the sequential
    /// fallback of the paged layout — compiling gather-aware modules is a
    /// compile-side follow-up). Sized once per role; steady-state calls
    /// reuse them, preserving the scratch-stable contract.
    kv_flat_k: Vec<f32>,
    kv_flat_v: Vec<f32>,
}

impl PjrtBackend {
    /// Open an artifact directory: parse + validate the manifest, create
    /// the PJRT CPU client. Executables compile lazily on first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {manifest_path:?}: {e}"))?;
        let contract = Contract::from_manifest(&manifest)?;
        let probe_variants = manifest
            .get("artifacts")
            .and_then(json::Json::as_arr)
            .map(|arts| {
                arts.iter()
                    .filter_map(|a| a.get("name").and_then(json::Json::as_str))
                    .filter_map(|n| n.strip_prefix("draft_probe_s").and_then(|s| s.parse().ok()))
                    .collect()
            })
            .unwrap_or_default();
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir,
            contract,
            exes: HashMap::new(),
            stats: RuntimeStats::default(),
            probe_variants,
            kv_flat_k: Vec::new(),
            kv_flat_v: Vec::new(),
        })
    }

    /// Materialize a paged KV view into the persistent flat staging
    /// buffers (`[L, cap, H, Dh]`), gathering every mapped logical row
    /// through the block table. Unmapped rows are zeroed — the additive
    /// mask closes them, but the uploaded tensor must still be fully
    /// defined. Flat views skip this entirely.
    fn materialize_kv(&mut self, kv: &KvView, dims: Dims) {
        let cap = self.contract.cache_cap;
        let rs = dims.heads * dims.d_head;
        let n = dims.cache_elems(cap);
        self.kv_flat_k.clear();
        self.kv_flat_k.resize(n, 0.0);
        self.kv_flat_v.clear();
        self.kv_flat_v.resize(n, 0.0);
        let rows = kv.mapped_rows().min(cap);
        for l in 0..dims.layers {
            for r in 0..rows {
                let src = kv.row_start(dims.layers, rs, l, r);
                let dst = (l * cap + r) * rs;
                self.kv_flat_k[dst..dst + rs].copy_from_slice(&kv.k[src..src + rs]);
                self.kv_flat_v[dst..dst + rs].copy_from_slice(&kv.v[src..src + rs]);
            }
        }
    }

    /// The artifact directory this backend was loaded from.
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Lazily compile a module by artifact name (e.g. `teacher_fused_s16`).
    fn exe(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.exes.contains_key(name) {
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let t0 = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
            self.stats.compiles += 1;
            self.stats.compile_secs += t0.elapsed().as_secs_f64();
            self.exes.insert(name.to_string(), exe);
        }
        Ok(&self.exes[name])
    }

    /// Pre-compile the variants a run will need (avoids first-call jitter
    /// in timed benchmarks).
    pub fn warmup(&mut self, mode: ExecMode, teacher_s: &[usize], draft_s: &[usize]) -> Result<()> {
        for s in teacher_s {
            self.exe(&format!("teacher_{}_s{s}", mode.as_str()))?;
        }
        for s in draft_s {
            self.exe(&format!("draft_s{s}"))?;
        }
        Ok(())
    }

    /// Upload one host tensor as an owned device buffer.
    ///
    /// NOTE: we deliberately avoid `PjRtLoadedExecutable::execute` (the
    /// literal-taking variant): its C shim converts every input literal to
    /// a device buffer with `.release()` and never frees it — a ~4 MB/call
    /// leak that OOM-killed early end-to-end runs. `buffer_from_host_buffer`
    /// returns a `PjRtBuffer` whose Drop does free, and `execute_b` borrows.
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("uploading f32 {dims:?}: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("uploading i32 {dims:?}: {e:?}"))
    }

    /// Execute a compiled module and land its outputs in the caller's
    /// scratch. The binding's `to_vec` still allocates one host `Vec`
    /// per output before the bounded `copy_from_slice` into the
    /// (pre-sized, reusable) scratch — so PJRT steps are *not* yet
    /// allocation-free, only scratch-stable. Output buffer donation
    /// (`to_literal` into a preallocated host buffer) removes both the
    /// intermediate `Vec`s and the copy; the scratch API keeps that a
    /// backend-local change (tracked in ROADMAP "Open items").
    fn run_module(
        &mut self,
        name: &str,
        inputs: &[xla::PjRtBuffer],
        upload_bytes: u64,
        want_probe: bool,
        dims: Dims,
        out: &mut StepScratch,
    ) -> Result<()> {
        let s_probe = want_probe; // tuple arity changes with probe outputs
        let t0 = Instant::now();
        let exe = self.exe(name)?;
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&inputs.iter().collect::<Vec<_>>())
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} outputs: {e:?}"))?;
        let mut parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} outputs: {e:?}"))?;
        let expect = if s_probe { 5 } else { 4 };
        if parts.len() != expect {
            bail!("{name}: expected {expect} outputs, got {}", parts.len());
        }
        let attn_top1 = if s_probe {
            let l = parts.pop().unwrap();
            Some(l.to_vec::<i32>().map_err(|e| anyhow::anyhow!("attn_top1: {e:?}"))?)
        } else {
            None
        };
        let v_new = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let k_new = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let feats = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let logits = parts.pop().unwrap().to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let s = logits.len() / self.contract.vocab;
        out.prepare(
            s,
            self.contract.vocab,
            self.contract.feat_dim,
            dims.layers,
            dims.heads,
            dims.d_head,
            attn_top1.is_some(),
        );
        let check = |got: usize, want: usize, what: &str| -> Result<()> {
            if got != want {
                bail!("{name}: {what} size {got} != expected {want}");
            }
            Ok(())
        };
        check(logits.len(), out.logits.len(), "logits")?;
        check(feats.len(), out.feats.len(), "feats")?;
        check(k_new.len(), out.k_new.len(), "k_new")?;
        check(v_new.len(), out.v_new.len(), "v_new")?;
        out.logits.copy_from_slice(&logits);
        out.feats.copy_from_slice(&feats);
        out.k_new.copy_from_slice(&k_new);
        out.v_new.copy_from_slice(&v_new);
        if let Some(a) = attn_top1 {
            check(a.len(), out.attn_top1.len(), "attn_top1")?;
            out.attn_top1.copy_from_slice(&a);
        }
        self.stats.executions += 1;
        self.stats.execute_secs += t0.elapsed().as_secs_f64();
        self.stats.upload_bytes += upload_bytes;
        Ok(())
    }
}

impl ModelBackend for PjrtBackend {
    fn contract(&self) -> &Contract {
        &self.contract
    }

    fn teacher_step(&mut self, mode: ExecMode, args: StepArgs, out: &mut StepScratch)
        -> Result<()> {
        let s = args.tokens.len();
        if !self.contract.teacher_s.contains(&s) {
            bail!("teacher_step: {s} is not a compiled S variant");
        }
        let d = self.contract.teacher;
        let cap = self.contract.cache_cap;
        let name = format!("teacher_{}_s{s}", mode.as_str());
        let cache_dims = [d.layers, cap, d.heads, d.d_head];
        if matches!(args.kv.index, KvIndex::Paged { .. }) {
            self.materialize_kv(&args.kv, d);
        }
        let (ck, cv): (&[f32], &[f32]) = match args.kv.index {
            KvIndex::Flat { .. } => (args.kv.k, args.kv.v),
            KvIndex::Paged { .. } => (&self.kv_flat_k, &self.kv_flat_v),
        };
        let inputs = vec![
            self.upload_i32(args.tokens, &[s])?,
            self.upload_i32(args.positions, &[s])?,
            self.upload_f32(args.mask, &[s, cap + s])?,
            self.upload_f32(ck, &cache_dims)?,
            self.upload_f32(cv, &cache_dims)?,
        ];
        let upload = (args.mask.len() + ck.len() + cv.len()) * 4 + s * 8;
        self.run_module(&name, &inputs, upload as u64, false, d, out)
    }

    fn draft_step(&mut self, args: StepArgs, out: &mut StepScratch) -> Result<()> {
        let s = args.tokens.len();
        if !self.contract.draft_s.contains(&s) {
            bail!("draft_step: {s} is not a compiled S variant");
        }
        let d = self.contract.draft;
        let cap = self.contract.cache_cap;
        let feats = args.feats_in.context("draft_step requires feats_in")?;
        // probe variants exist only for a subset of S
        let probe = args.probe && self.probe_variants.contains(&s);
        let name = if probe { format!("draft_probe_s{s}") } else { format!("draft_s{s}") };
        let cache_dims = [d.layers, cap, d.heads, d.d_head];
        if matches!(args.kv.index, KvIndex::Paged { .. }) {
            self.materialize_kv(&args.kv, d);
        }
        let (ck, cv): (&[f32], &[f32]) = match args.kv.index {
            KvIndex::Flat { .. } => (args.kv.k, args.kv.v),
            KvIndex::Paged { .. } => (&self.kv_flat_k, &self.kv_flat_v),
        };
        let inputs = vec![
            self.upload_i32(args.tokens, &[s])?,
            self.upload_f32(feats, &[s, self.contract.feat_dim])?,
            self.upload_i32(args.positions, &[s])?,
            self.upload_f32(args.mask, &[s, cap + s])?,
            self.upload_f32(ck, &cache_dims)?,
            self.upload_f32(cv, &cache_dims)?,
        ];
        let upload = (args.mask.len() + ck.len() + cv.len() + feats.len()) * 4 + s * 8;
        self.run_module(&name, &inputs, upload as u64, probe, d, out)
    }

    fn name(&self) -> &'static str {
        "pjrt-cpu"
    }
}
