//! The production backend: artifact registry + PJRT execution behind the
//! plan → bind → execute protocol.
//!
//! * **Plan** — the capabilities table is parsed from the artifact
//!   manifest ([`Capabilities::from_manifest`]); every launch resolves a
//!   [`LaunchPlan`] whose [`ModuleKey`] names the artifact
//!   (`teacher_fused_s16`, `teacher_fused_b4_s32`, …), so no shape ever
//!   `bail!`s — an uncovered request surfaces as a typed
//!   [`crate::backend::PlanError`] listing the compiled variants.
//! * **Bind** — when the artifact set ships a `kv_append_{role}_n{N}`
//!   scatter-update module, [`ModelBackend::bind_kv`] keeps a
//!   conversation cache device-resident: the bound `[L, cap, H, Dh]`
//!   buffers are uploaded once and retained ([`xla::PjRtBuffer`]s held
//!   across launches); each ticketed step ships only the dirty-row delta
//!   and applies it device-side through the scatter module, so
//!   steady-state `upload_bytes` per step no longer scales with the
//!   cache capacity. Without the scatter module, `bind_kv` answers
//!   [`crate::backend::PlanError::SessionUnsupported`] and callers fall
//!   back to full-view upload (the pre-session behaviour, and always the
//!   eager/debug path's behaviour).
//! * **Execute** — module outputs land through [`xla::Literal::read_into`]
//!   directly in the prepared [`StepScratch`] slices (output donation to
//!   host scratch): no intermediate per-output `Vec`. Fused
//!   `teacher_{mode}_b{B}_s{S}` artifacts run a whole verification group
//!   as **one** launch ([`ModelBackend::execute_batch`]); groups wider
//!   than any compiled variant are split by the
//!   [`crate::coordinator::FusedVerifier`], never silently emulated.
//!   The overlapped pair [`ModelBackend::begin_execute_batch`] /
//!   [`ModelBackend::await_batch`] splits the same launch into its
//!   dispatch half (uploads + `execute_b`, result buffers retained) and
//!   its readback half (`to_literal_sync` into the prepared scratch), so
//!   the pipelined serve loop can run host work while a fused launch is
//!   in flight.
//!
//! Fused launches with bound sessions still upload the staged per-request
//! caches (the fused modules take a stacked `[B, L, cap, H, Dh]` input;
//! feeding retained per-conversation buffers needs the gather-aware
//! modules tracked in ROADMAP) — the mirrors are kept in sync regardless,
//! so the single-request steps around a fused tick stay delta-priced.

use crate::backend::{
    BatchStepArgs, KvIndex, KvSession, KvView, LaunchPlan, LaunchToken, ModelBackend, ModuleKey,
    ModuleRole, PlanError, SessionTicket, StepArgs, StepScratch,
};
use crate::config::{Capabilities, Contract, Dims, ExecMode};
use crate::json;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Statistics about artifact loading / execution (surfaced in manifests
/// and the §Perf logs).
#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// Modules compiled (lazily, on first use).
    pub compiles: u64,
    /// Total compile wall time, seconds.
    pub compile_secs: f64,
    /// Module executions (fused batched verification counts once;
    /// session scatter-updates count their own launches).
    pub executions: u64,
    /// Total execution wall time, seconds.
    pub execute_secs: f64,
    /// Host->device bytes shipped (per-call tensors; bound sessions ship
    /// dirty-row deltas instead of full caches).
    pub upload_bytes: u64,
}

/// Persistent host staging for one role's materialized paged views: the
/// flat-cache modules take a contiguous `[L, cap, H, Dh]` input, so a
/// block-table view is gathered here before upload. Sized once; each
/// call re-gathers only the mapped rows and zeroes only rows a previous
/// (larger) materialization left behind.
#[derive(Default)]
struct FlatStage {
    k: Vec<f32>,
    v: Vec<f32>,
    /// Rows holding live gathered data from the previous call.
    rows: usize,
}

/// One fused launch dispatched but not yet read back: the un-read
/// device result buffers from `execute_b`, the input buffers kept alive
/// until readback (PJRT may still be consuming them), and the readback
/// dimensions. Held in [`PjrtBackend::pending`] between
/// [`ModelBackend::begin_execute_batch`] and
/// [`ModelBackend::await_batch`]; the eager
/// [`ModelBackend::execute_batch`] path reads it back immediately.
struct PendingLaunch {
    name: String,
    result: Vec<Vec<xla::PjRtBuffer>>,
    inputs: Vec<xla::PjRtBuffer>,
    bk: usize,
    sk: usize,
}

/// One bound conversation cache: a host mirror plus retained device
/// buffers updated through the `kv_append` scatter module.
struct DeviceSession {
    role: ModuleRole,
    /// Host mirror, flat `[L, cap, H, Dh]` (logical-row indexed).
    host_k: Vec<f32>,
    host_v: Vec<f32>,
    /// Mirrored readable rows.
    rows: usize,
    /// Device-resident (k, v) cache buffers; `None` after a device-side
    /// failure — the next step uploads the mirror wholesale.
    dev: Option<(xla::PjRtBuffer, xla::PjRtBuffer)>,
}

/// The production [`ModelBackend`]: AOT HLO artifacts executed through
/// the PJRT CPU client (see the module docs for the protocol).
pub struct PjrtBackend {
    client: xla::PjRtClient,
    dir: PathBuf,
    contract: Contract,
    caps: Capabilities,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Compile/execute/upload counters (surfaced in manifests).
    pub stats: RuntimeStats,
    /// Per-role paged-view materialization staging (teacher, draft).
    stage: [FlatStage; 2],
    /// Fused-batch cache staging (`[B, L, cap, H, Dh]`, both sides).
    fused_k: Vec<f32>,
    fused_v: Vec<f32>,
    /// Live gathered rows per fused slot from the previous stacking
    /// (stale-tail zeroing bound, like [`FlatStage::rows`]).
    fused_rows: Vec<usize>,
    /// Reusable launch-input vector (buffer handles; capacity reused).
    inputs: Vec<xla::PjRtBuffer>,
    /// Session delta staging (`[L, N, H, Dh]` + row indices).
    delta_k: Vec<f32>,
    delta_v: Vec<f32>,
    delta_rows: Vec<i32>,
    /// Bound KV sessions, keyed by session id.
    sessions: HashMap<u64, DeviceSession>,
    next_session: u64,
    /// Overlapped fused launches dispatched but not yet awaited, keyed
    /// by [`LaunchToken`] id.
    pending: HashMap<u64, PendingLaunch>,
    next_launch: u64,
}

/// Staging-array index of a role.
fn stage_idx(role: ModuleRole) -> usize {
    match role {
        ModuleRole::Teacher => 0,
        ModuleRole::Draft => 1,
    }
}

/// Gather logical rows `[lo, hi)` of a (gather-aware) view into flat
/// `[L, cap, H, Dh]` destination storage — the one row-copy loop shared
/// by mirror sync, session bind/rebind, paged-view materialization and
/// fused-cache stacking.
fn gather_rows_flat(
    kv: &KvView,
    dst_k: &mut [f32],
    dst_v: &mut [f32],
    lo: usize,
    hi: usize,
    layers: usize,
    rs: usize,
    cap: usize,
) {
    for r in lo..hi {
        for l in 0..layers {
            let src = kv.row_start(layers, rs, l, r);
            let dst = (l * cap + r) * rs;
            dst_k[dst..dst + rs].copy_from_slice(&kv.k[src..src + rs]);
            dst_v[dst..dst + rs].copy_from_slice(&kv.v[src..src + rs]);
        }
    }
}

impl PjrtBackend {
    /// Open an artifact directory: parse + validate the manifest
    /// (contract fields *and* the artifact naming schema), build the
    /// capabilities table, create the PJRT CPU client. Executables
    /// compile lazily on first use.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {manifest_path:?}: {e}"))?;
        let contract = Contract::from_manifest(&manifest)?;
        let caps = Capabilities::from_manifest(&manifest)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            dir,
            contract,
            caps,
            exes: HashMap::new(),
            stats: RuntimeStats::default(),
            stage: [FlatStage::default(), FlatStage::default()],
            fused_k: Vec::new(),
            fused_v: Vec::new(),
            fused_rows: Vec::new(),
            inputs: Vec::new(),
            delta_k: Vec::new(),
            delta_v: Vec::new(),
            delta_rows: Vec::new(),
            sessions: HashMap::new(),
            next_session: 0,
            pending: HashMap::new(),
            next_launch: 0,
        })
    }

    /// Role dimensions of the contract.
    fn dims_of(&self, role: ModuleRole) -> Dims {
        match role {
            ModuleRole::Teacher => self.contract.teacher,
            ModuleRole::Draft => self.contract.draft,
        }
    }

    /// Materialize a paged KV view into the role's persistent flat
    /// staging (`[L, cap, H, Dh]`), gathering every mapped logical row
    /// through the block table. The staging is sized **once** per role
    /// and reused across calls; only rows past this call's mapped region
    /// that a previous (larger) materialization wrote are re-zeroed —
    /// not the whole buffer (the old per-call full zero-fill was pure
    /// waste: `cap * L * H * Dh` writes per step).
    fn materialize_kv(&mut self, kv: &KvView, role: ModuleRole) {
        let dims = self.dims_of(role);
        let cap = self.contract.cache_cap;
        let rs = dims.heads * dims.d_head;
        let n = dims.cache_elems(cap);
        let stage = &mut self.stage[stage_idx(role)];
        if stage.k.len() < n {
            stage.k.resize(n, 0.0);
            stage.v.resize(n, 0.0);
            stage.rows = 0;
        }
        let rows = kv.mapped_rows().min(cap);
        let prev = stage.rows.min(cap);
        gather_rows_flat(kv, &mut stage.k, &mut stage.v, 0, rows, dims.layers, rs, cap);
        if prev > rows {
            for l in 0..dims.layers {
                let z0 = (l * cap + rows) * rs;
                let z1 = (l * cap + prev) * rs;
                stage.k[z0..z1].fill(0.0);
                stage.v[z0..z1].fill(0.0);
            }
        }
        stage.rows = rows;
    }

    /// The artifact directory this backend was loaded from.
    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    /// Compile `name` if it is not already resident. The launch path
    /// then does a single map lookup per call (the old
    /// `contains_key` + index pair did two on every launch).
    fn ensure_compiled(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let t0 = Instant::now();
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("artifact path not utf-8")?)
                .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        self.stats.compiles += 1;
        self.stats.compile_secs += t0.elapsed().as_secs_f64();
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Pre-compile the variants a run will need (avoids first-call jitter
    /// in timed benchmarks).
    pub fn warmup(&mut self, mode: ExecMode, teacher_s: &[usize], draft_s: &[usize]) -> Result<()> {
        for &s in teacher_s {
            self.ensure_compiled(&ModuleKey::teacher(mode, s).artifact_name())?;
        }
        for &s in draft_s {
            self.ensure_compiled(&ModuleKey::draft(s, false).artifact_name())?;
        }
        Ok(())
    }

    /// Upload one host tensor as an owned device buffer.
    ///
    /// NOTE: we deliberately avoid `PjRtLoadedExecutable::execute` (the
    /// literal-taking variant): its C shim converts every input literal to
    /// a device buffer with `.release()` and never frees it — a ~4 MB/call
    /// leak that OOM-killed early end-to-end runs. `buffer_from_host_buffer`
    /// returns a `PjRtBuffer` whose Drop does free, and `execute_b` borrows.
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("uploading f32 {dims:?}: {e:?}"))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("uploading i32 {dims:?}: {e:?}"))
    }

    /// Land a launch's tuple outputs in the caller's **prepared** scratch
    /// through `Literal::read_into` (output donation to host scratch: no
    /// intermediate per-output `Vec`). `probe` selects the 5-output
    /// arity.
    fn read_outputs(
        name: &str,
        result: &[Vec<xla::PjRtBuffer>],
        probe: bool,
        out: &mut StepScratch,
    ) -> Result<()> {
        let tuple = result
            .first()
            .and_then(|r| r.first())
            .with_context(|| format!("{name}: empty execution result"))?
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetching {name} outputs: {e:?}"))?;
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untupling {name} outputs: {e:?}"))?;
        let expect = if probe { 5 } else { 4 };
        if parts.len() != expect {
            bail!("{name}: expected {expect} outputs, got {}", parts.len());
        }
        let read = |i: usize, dst: &mut [f32], what: &str| -> Result<()> {
            parts[i]
                .read_into(dst)
                .map_err(|e| anyhow::anyhow!("{name}: reading {what}: {e:?}"))
        };
        read(0, &mut out.logits, "logits")?;
        read(1, &mut out.feats, "feats")?;
        read(2, &mut out.k_new, "k_new")?;
        read(3, &mut out.v_new, "v_new")?;
        if probe {
            parts[4]
                .read_into(&mut out.attn_top1)
                .map_err(|e| anyhow::anyhow!("{name}: reading attn_top1: {e:?}"))?;
        }
        Ok(())
    }

    /// Sync a bound session with its cache's dirty delta: update the host
    /// mirror from the (gather-aware) live view, then apply the same
    /// rows device-side through the `kv_append_{role}_n{N}` scatter
    /// module (chunked to the compiled delta width; short deltas pad by
    /// repeating their last row — idempotent writes). Charges only the
    /// delta bytes: this is the transfer that replaces the per-step full
    /// cache upload.
    fn sync_session(&mut self, t: &SessionTicket, kv: &KvView, role: ModuleRole) -> Result<()> {
        let mut sess = self
            .sessions
            .remove(&t.id)
            .ok_or(PlanError::UnknownSession { id: t.id })?;
        if sess.role != role {
            let bound = sess.role;
            self.sessions.insert(t.id, sess);
            return Err(PlanError::RoleMismatch { bound, requested: role }.into());
        }
        let dims = self.dims_of(role);
        let cap = self.contract.cache_cap;
        let rs = dims.heads * dims.d_head;
        let range = t.sync_range();
        gather_rows_flat(
            kv,
            &mut sess.host_k,
            &mut sess.host_v,
            range.start,
            range.end,
            dims.layers,
            rs,
            cap,
        );
        sess.rows = t.rows;
        if !range.is_empty() {
            if let Some((dk, dv)) = sess.dev.take() {
                match self.kv_append(&sess, dk, dv, range.clone(), role) {
                    Ok(pair) => sess.dev = Some(pair),
                    Err(e) => {
                        self.sessions.insert(t.id, sess);
                        return Err(e);
                    }
                }
            }
            self.stats.upload_bytes += (range.len() * 2 * dims.layers * rs * 4) as u64;
        }
        self.sessions.insert(t.id, sess);
        Ok(())
    }

    /// Apply mirror rows `range` to the retained device buffers through
    /// the scatter-update module, returning the updated buffers.
    fn kv_append(
        &mut self,
        sess: &DeviceSession,
        mut dk: xla::PjRtBuffer,
        mut dv: xla::PjRtBuffer,
        range: Range<usize>,
        role: ModuleRole,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        let dims = self.dims_of(role);
        let cap = self.contract.cache_cap;
        let rs = dims.heads * dims.d_head;
        let n_var = self
            .caps
            .kv_append_width(role, range.len())
            .with_context(|| format!("no kv_append module for role {}", role.as_str()))?;
        let mut r0 = range.start;
        while r0 < range.end {
            let take = (range.end - r0).min(n_var);
            self.delta_k.clear();
            self.delta_k.resize(dims.layers * n_var * rs, 0.0);
            self.delta_v.clear();
            self.delta_v.resize(dims.layers * n_var * rs, 0.0);
            self.delta_rows.clear();
            self.delta_rows.resize(n_var, 0);
            for i in 0..n_var {
                // pad by repeating the last live row: duplicate indices
                // re-write identical data, so padding is a no-op
                let r = r0 + i.min(take - 1);
                self.delta_rows[i] = r as i32;
                for l in 0..dims.layers {
                    let src = (l * cap + r) * rs;
                    let dst = (l * n_var + i) * rs;
                    self.delta_k[dst..dst + rs].copy_from_slice(&sess.host_k[src..src + rs]);
                    self.delta_v[dst..dst + rs].copy_from_slice(&sess.host_v[src..src + rs]);
                }
            }
            let name = format!("kv_append_{}_n{}", role.as_str(), n_var);
            self.ensure_compiled(&name)?;
            let rows_buf = self.upload_i32(&self.delta_rows, &[n_var])?;
            let dkb =
                self.upload_f32(&self.delta_k, &[dims.layers, n_var, dims.heads, dims.d_head])?;
            let dvb =
                self.upload_f32(&self.delta_v, &[dims.layers, n_var, dims.heads, dims.d_head])?;
            let t0 = Instant::now();
            let exe = self.exes.get(&name).expect("compiled above");
            let refs: [&xla::PjRtBuffer; 5] = [&dk, &dv, &rows_buf, &dkb, &dvb];
            let mut result = exe
                .execute_b::<&xla::PjRtBuffer>(&refs)
                .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
            self.stats.executions += 1;
            self.stats.execute_secs += t0.elapsed().as_secs_f64();
            let tuple_buf = result
                .first_mut()
                .and_then(|r| r.pop())
                .with_context(|| format!("{name}: empty execution result"))?;
            let mut outs = tuple_buf
                .destructure_tuple()
                .map_err(|e| anyhow::anyhow!("{name}: destructuring outputs: {e:?}"))?;
            if outs.len() != 2 {
                bail!("{name}: expected 2 outputs, got {}", outs.len());
            }
            dv = outs.pop().expect("len checked");
            dk = outs.pop().expect("len checked");
            r0 += take;
        }
        Ok((dk, dv))
    }

    /// The dispatch half of a true fused `[B, S]` launch: session sync,
    /// cache stacking, uploads and `execute_b` — everything up to (but
    /// not including) the host-blocking tuple readback. Returns the
    /// un-read [`PendingLaunch`]; the eager batch path reads it back
    /// immediately ([`PjrtBackend::readback`]), the overlapped path
    /// parks it in [`PjrtBackend::pending`] until the await. Shared so
    /// the two paths cannot drift.
    fn fused_dispatch(
        &mut self,
        plan: &LaunchPlan,
        args: &BatchStepArgs,
        out: &mut StepScratch,
    ) -> Result<PendingLaunch> {
        let (bk, sk) = (plan.key.b, plan.key.s);
        let dims = self.contract.teacher;
        let cap = self.contract.cache_cap;
        let rs = dims.heads * dims.d_head;
        let name = plan.key.artifact_name();
        self.ensure_compiled(&name)?;
        // keep every ticketed mirror current (the ticket is consumed by
        // this launch whether or not the fused module can read retained
        // buffers — see the module docs)
        for req in args.reqs.iter() {
            if let Some(t) = req.session {
                self.sync_session(&t, &req.kv, ModuleRole::Teacher)?;
            }
        }
        // Stack per-request caches ([B_key, L, cap, H, Dh]). The staging
        // is sized once and reused; like materialize_kv, each slot zeroes
        // only rows a previous (larger) stacking left behind instead of
        // memsetting the whole multi-MB pair every launch.
        let n1 = dims.cache_elems(cap);
        let total = bk * n1;
        if self.fused_k.len() < total {
            self.fused_k.resize(total, 0.0);
            self.fused_v.resize(total, 0.0);
        }
        if self.fused_rows.len() < bk {
            self.fused_rows.resize(bk, 0);
        }
        for bi in 0..bk {
            let rows = args
                .reqs
                .get(bi)
                .map(|req| req.kv.mapped_rows().min(cap))
                .unwrap_or(0);
            let base = bi * n1;
            if let Some(req) = args.reqs.get(bi) {
                gather_rows_flat(
                    &req.kv,
                    &mut self.fused_k[base..base + n1],
                    &mut self.fused_v[base..base + n1],
                    0,
                    rows,
                    dims.layers,
                    rs,
                    cap,
                );
            }
            let prev = self.fused_rows[bi].min(cap);
            if prev > rows {
                for l in 0..dims.layers {
                    let z0 = base + (l * cap + rows) * rs;
                    let z1 = base + (l * cap + prev) * rs;
                    self.fused_k[z0..z1].fill(0.0);
                    self.fused_v[z0..z1].fill(0.0);
                }
            }
            self.fused_rows[bi] = rows;
        }
        out.prepare_batch(
            bk,
            sk,
            self.contract.vocab,
            self.contract.feat_dim,
            dims.layers,
            dims.heads,
            dims.d_head,
            false,
        );
        let mut inputs = std::mem::take(&mut self.inputs);
        inputs.clear();
        let run = (|| -> Result<Vec<Vec<xla::PjRtBuffer>>> {
            inputs.push(self.upload_i32(args.tokens, &[bk * sk])?);
            inputs.push(self.upload_i32(args.positions, &[bk * sk])?);
            inputs.push(self.upload_f32(args.mask, &[bk, sk, cap + sk])?);
            let cache_dims = [bk, dims.layers, cap, dims.heads, dims.d_head];
            // slice to this launch's extent: the staging may be larger
            // after a previous wider group
            inputs.push(self.upload_f32(&self.fused_k[..total], &cache_dims)?);
            inputs.push(self.upload_f32(&self.fused_v[..total], &cache_dims)?);
            let upload = (args.mask.len() * 4 + bk * sk * 8 + 2 * total * 4) as u64;
            let t0 = Instant::now();
            let exe = self.exes.get(&name).expect("compiled above");
            let result = exe
                .execute_b::<xla::PjRtBuffer>(&inputs)
                .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
            self.stats.executions += 1;
            self.stats.execute_secs += t0.elapsed().as_secs_f64();
            self.stats.upload_bytes += upload;
            Ok(result)
        })();
        match run {
            Ok(result) => Ok(PendingLaunch { name, result, inputs, bk, sk }),
            Err(e) => {
                inputs.clear();
                self.inputs = inputs;
                Err(e)
            }
        }
    }

    /// The readback half of a fused launch: block on the result tuple,
    /// land the outputs in the prepared scratch, recycle the input
    /// buffer vector. Readback wall time is charged to
    /// [`RuntimeStats::execute_secs`] — under the overlapped path this
    /// is the residual wait the host did *not* manage to hide.
    fn readback(&mut self, p: PendingLaunch, out: &mut StepScratch) -> Result<()> {
        let PendingLaunch { name, result, mut inputs, bk, sk } = p;
        let dims = self.contract.teacher;
        // re-prepare defensively: the overlapped caller may have used the
        // scratch between begin and await (prepare is idempotent on
        // already-correct shapes, and outputs are fully overwritten)
        out.prepare_batch(
            bk,
            sk,
            self.contract.vocab,
            self.contract.feat_dim,
            dims.layers,
            dims.heads,
            dims.d_head,
            false,
        );
        let t0 = Instant::now();
        let res = Self::read_outputs(&name, &result, false, out);
        self.stats.execute_secs += t0.elapsed().as_secs_f64();
        inputs.clear();
        if inputs.capacity() > self.inputs.capacity() {
            self.inputs = inputs;
        }
        res
    }
}

impl ModelBackend for PjrtBackend {
    fn contract(&self) -> &Contract {
        &self.contract
    }

    fn capabilities(&self) -> &Capabilities {
        &self.caps
    }

    fn execute(&mut self, plan: &LaunchPlan, args: StepArgs, out: &mut StepScratch) -> Result<()> {
        let role = plan.key.role;
        let dims = self.dims_of(role);
        let cap = self.contract.cache_cap;
        let s = args.tokens.len();
        let name = plan.key.artifact_name();
        // the compiled module's input shapes are [key.s]/[key.s, cap+key.s]:
        // a caller that planned but did not pad would otherwise surface as
        // an opaque XLA argument-shape error deep inside the launch (and
        // pass silently on the shape-agnostic sim)
        anyhow::ensure!(
            s == plan.key.s,
            "inputs padded to {s} slots but the plan resolved '{name}' — callers must pad \
             token/position/mask staging to the planned variant before executing"
        );
        // session sync first (mutable phase; may launch kv_append)
        let ticket = match args.session {
            Some(t) => {
                self.sync_session(&t, &args.kv, role)?;
                Some(t)
            }
            None => None,
        };
        // paged view without a session: gather into the role staging
        if ticket.is_none() && matches!(args.kv.index, KvIndex::Paged { .. }) {
            self.materialize_kv(&args.kv, role);
        }
        out.prepare(
            s,
            self.contract.vocab,
            self.contract.feat_dim,
            dims.layers,
            dims.heads,
            dims.d_head,
            plan.key.probe,
        );
        let mut inputs = std::mem::take(&mut self.inputs);
        inputs.clear();
        let run = (|| -> Result<()> {
            let mut upload = (s * 8 + args.mask.len() * 4) as u64;
            inputs.push(self.upload_i32(args.tokens, &[s])?);
            if role == ModuleRole::Draft {
                let feats = args.feats_in.context("draft step requires feats_in")?;
                inputs.push(self.upload_f32(feats, &[s, self.contract.feat_dim])?);
                upload += (feats.len() * 4) as u64;
            }
            inputs.push(self.upload_i32(args.positions, &[s])?);
            inputs.push(self.upload_f32(args.mask, &[s, cap + s])?);
            let cache_dims = [dims.layers, cap, dims.heads, dims.d_head];
            // cache inputs: retained device buffers > session mirror >
            // (materialized) host view
            let dev_resident = ticket
                .map(|t| self.sessions.get(&t.id).is_some_and(|sess| sess.dev.is_some()))
                .unwrap_or(false);
            if !dev_resident {
                let n = dims.cache_elems(cap);
                let (ck, cv): (&[f32], &[f32]) = if let Some(t) = ticket {
                    let sess = &self.sessions[&t.id];
                    (&sess.host_k, &sess.host_v)
                } else {
                    match args.kv.index {
                        KvIndex::Flat { .. } => (args.kv.k, args.kv.v),
                        KvIndex::Paged { .. } => {
                            let stage = &self.stage[stage_idx(role)];
                            (&stage.k[..n], &stage.v[..n])
                        }
                    }
                };
                inputs.push(self.upload_f32(ck, &cache_dims)?);
                inputs.push(self.upload_f32(cv, &cache_dims)?);
                upload += ((ck.len() + cv.len()) * 4) as u64;
            }
            let t0 = Instant::now();
            let exe = self.exes.get(&name).expect("compiled above");
            let result = if dev_resident {
                let t = ticket.expect("dev_resident implies ticket");
                let (dk, dv) = self.sessions[&t.id].dev.as_ref().expect("dev checked");
                let refs: Vec<&xla::PjRtBuffer> =
                    inputs.iter().chain([dk, dv]).collect();
                exe.execute_b::<&xla::PjRtBuffer>(&refs)
            } else {
                exe.execute_b::<xla::PjRtBuffer>(&inputs)
            }
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
            Self::read_outputs(&name, &result, plan.key.probe, out)?;
            self.stats.executions += 1;
            self.stats.execute_secs += t0.elapsed().as_secs_f64();
            self.stats.upload_bytes += upload;
            Ok(())
        })();
        inputs.clear();
        self.inputs = inputs;
        run
    }

    /// True fused `[B, S]` dispatch: one `teacher_{mode}_b{B}_s{S}`
    /// launch verifies the whole group. Inputs are the verifier-staged
    /// `[B_key * S_key]` tokens/positions, the `[B_key, S_key, cap +
    /// S_key]` mask block, and the per-request caches stacked into a
    /// `[B_key, L, cap, H, Dh]` staging pair (group-padding requests
    /// contribute zero blocks). A `B_key == 1` plan names the plain
    /// single-request artifact, whose compiled input *ranks* differ from
    /// the batched layout (`[S, cap+S]` mask, unstacked caches), so it is
    /// routed through [`ModelBackend::execute`] instead.
    fn execute_batch(
        &mut self,
        plan: &LaunchPlan,
        args: BatchStepArgs,
        out: &mut StepScratch,
    ) -> Result<()> {
        let (bk, sk) = (plan.key.b, plan.key.s);
        anyhow::ensure!(!args.reqs.is_empty(), "execute_batch with an empty group");
        if args.s_max != sk || args.tokens.len() != bk * sk || args.reqs.len() > bk {
            // staging not padded to the planned variant (a direct
            // `teacher_step_batch` caller rather than the FusedVerifier,
            // which pads): run the correct sequential emulation instead
            // of launching a mismatched module
            return self.emulate_batch(plan.key.mode, args, out);
        }
        if bk == 1 {
            // width-1 group: the plan names the single-request module
            // (no batch axis compiled) — same data, unbatched ranks
            let req = args.reqs[0];
            return self.execute(
                plan,
                StepArgs {
                    tokens: args.tokens,
                    positions: args.positions,
                    mask: args.mask,
                    kv: req.kv,
                    feats_in: None,
                    probe: false,
                    session: req.session,
                },
                out,
            );
        }
        let p = self.fused_dispatch(plan, &args, out)?;
        self.readback(p, out)
    }

    /// Overlapped fused dispatch: run the staging/upload/`execute_b`
    /// half of the batch launch, retaining the un-read result buffers,
    /// and defer the host-blocking tuple readback to
    /// [`ModelBackend::await_batch`] — between the two, the PJRT runtime
    /// owns the computation and the host is free to stage the next wave.
    /// The `bk == 1` single-request route (the plan names the unbatched
    /// module) and the staging-mismatch emulation route have no deferred
    /// half and complete eagerly ([`LaunchToken::completed`]).
    fn begin_execute_batch(
        &mut self,
        plan: &LaunchPlan,
        args: BatchStepArgs,
        out: &mut StepScratch,
    ) -> Result<LaunchToken> {
        let (bk, sk) = (plan.key.b, plan.key.s);
        anyhow::ensure!(!args.reqs.is_empty(), "begin_execute_batch with an empty group");
        if args.s_max != sk || args.tokens.len() != bk * sk || args.reqs.len() > bk || bk == 1 {
            self.execute_batch(plan, args, out)?;
            return Ok(LaunchToken::completed());
        }
        let p = self.fused_dispatch(plan, &args, out)?;
        self.next_launch += 1;
        let id = self.next_launch;
        self.pending.insert(id, p);
        Ok(LaunchToken { id })
    }

    fn await_batch(&mut self, token: LaunchToken, out: &mut StepScratch) -> Result<()> {
        if token.is_completed() {
            return Ok(());
        }
        let p = self
            .pending
            .remove(&token.id)
            .with_context(|| format!("await_batch: unknown pjrt launch token {}", token.id))?;
        self.readback(p, out)
    }

    fn bind_kv(
        &mut self,
        role: ModuleRole,
        view: KvView,
        rows: usize,
    ) -> Result<KvSession, PlanError> {
        if !self.caps.supports_kv_append(role) {
            // no scatter-update module in this artifact set: sessions
            // would re-upload full caches anyway — fall back loudly
            return Err(PlanError::SessionUnsupported { backend: "pjrt-cpu" });
        }
        let dims = self.dims_of(role);
        let cap = self.contract.cache_cap;
        let rs = dims.heads * dims.d_head;
        let n = dims.cache_elems(cap);
        let mut sess = DeviceSession {
            role,
            host_k: vec![0.0; n],
            host_v: vec![0.0; n],
            rows: 0,
            dev: None,
        };
        gather_rows_flat(
            &view,
            &mut sess.host_k,
            &mut sess.host_v,
            0,
            rows.min(cap),
            dims.layers,
            rs,
            cap,
        );
        sess.rows = rows;
        let cache_dims = [dims.layers, cap, dims.heads, dims.d_head];
        let dk = self
            .upload_f32(&sess.host_k, &cache_dims)
            .map_err(|e| PlanError::SessionInit { reason: format!("{e:#}") })?;
        let dv = self
            .upload_f32(&sess.host_v, &cache_dims)
            .map_err(|e| PlanError::SessionInit { reason: format!("{e:#}") })?;
        sess.dev = Some((dk, dv));
        self.stats.upload_bytes += (2 * n * 4) as u64;
        let id = self.next_session;
        self.next_session += 1;
        self.sessions.insert(id, sess);
        Ok(KvSession { id, role })
    }

    fn rebind_kv(
        &mut self,
        session: &KvSession,
        view: KvView,
        rows: usize,
    ) -> Result<(), PlanError> {
        let dims = self.dims_of(session.role);
        let cap = self.contract.cache_cap;
        let rs = dims.heads * dims.d_head;
        {
            let sess = self
                .sessions
                .get_mut(&session.id)
                .ok_or(PlanError::UnknownSession { id: session.id })?;
            gather_rows_flat(
                &view,
                &mut sess.host_k,
                &mut sess.host_v,
                0,
                rows.min(cap),
                dims.layers,
                rs,
                cap,
            );
            sess.rows = rows;
            sess.dev = None;
        }
        let cache_dims = [dims.layers, cap, dims.heads, dims.d_head];
        let (dk, dv) = {
            let sess = &self.sessions[&session.id];
            let dk = self
                .upload_f32(&sess.host_k, &cache_dims)
                .map_err(|e| PlanError::SessionInit { reason: format!("{e:#}") })?;
            let dv = self
                .upload_f32(&sess.host_v, &cache_dims)
                .map_err(|e| PlanError::SessionInit { reason: format!("{e:#}") })?;
            (dk, dv)
        };
        self.sessions.get_mut(&session.id).expect("present above").dev = Some((dk, dv));
        self.stats.upload_bytes += (2 * dims.cache_elems(cap) * 4) as u64;
        Ok(())
    }

    fn unbind_kv(&mut self, session: KvSession) {
        self.sessions.remove(&session.id);
    }

    fn name(&self) -> &'static str {
        "pjrt-cpu"
    }
}
