//! Tensorization micro-bench: cost of the §3.2 safe-indexing scheme.
//!
//! Ablation axis (DESIGN.md §5): dummy-root tensorization *with* the
//! structural invariant checks vs *without* — quantifying what the
//! paper's "lightweight relative to a teacher forward" claim costs here.

use eagle_pangu::tree::{SpecTree, Tensorized};
use eagle_pangu::util::bench::{bench, black_box};
use eagle_pangu::util::SplitMix64;

fn random_tree(budget: usize, topk: usize, seed: u64) -> SpecTree {
    let mut rng = SplitMix64::new(seed);
    let mut tree = SpecTree::with_root(5);
    let mut frontier = vec![0usize];
    let mut added = 0;
    while added < budget && !frontier.is_empty() {
        let mut next = Vec::new();
        for &p in &frontier {
            for _ in 0..rng.range(1, topk as u64 + 1) {
                if added >= budget {
                    break;
                }
                next.push(tree.add_child(p, rng.range(2, 512) as i32, -0.5));
                added += 1;
            }
        }
        frontier = next;
    }
    tree
}

fn main() {
    println!("== tensorize: dummy-root arrays + ancestor table (paper §3.2) ==");
    for (m, s_pad) in [(15, 16usize), (63, 64), (255, 256)] {
        let tree = random_tree(m, 4, 42);
        bench(&format!("tensorize_checked_m{m}_s{s_pad}"), 20.0, 7, || {
            black_box(Tensorized::from_tree(&tree, s_pad, true).unwrap());
        });
        bench(&format!("tensorize_unchecked_m{m}_s{s_pad}"), 20.0, 7, || {
            black_box(Tensorized::from_tree(&tree, s_pad, false).unwrap());
        });
        let tens = Tensorized::from_tree(&tree, s_pad, false).unwrap();
        bench(&format!("invariant_checks_only_m{m}"), 20.0, 7, || {
            black_box(tens.check_invariants().unwrap());
        });
    }
}
