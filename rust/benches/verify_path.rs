//! Verification-path bench (paper §4.1 two-mode protocol): the cost of
//! one teacher verification step under the fused (Pallas) vs eager
//! artifacts, per S variant, plus draft-step cost — the per-call numbers
//! that explain the end-to-end E1/E2 results.
//!
//! Requires `make artifacts`; skips gracefully otherwise.

use eagle_pangu::backend::{KvView, ModelBackend, StepArgs, StepScratch};
use eagle_pangu::config::contract::NEG_INF;
use eagle_pangu::config::ExecMode;
use eagle_pangu::runtime::PjrtBackend;
use eagle_pangu::util::bench::{bench, black_box};
use eagle_pangu::util::SplitMix64;

fn main() {
    let Ok(mut backend) = PjrtBackend::load("artifacts") else {
        eprintln!("SKIP verify_path: artifacts/ missing (run `make artifacts`)");
        return;
    };
    let c = backend.contract().clone();
    let cap = c.cache_cap;
    let mut rng = SplitMix64::new(1);
    let kn = c.teacher.cache_elems(cap);
    let k: Vec<f32> = (0..kn).map(|_| rng.f32_pm1() * 0.1).collect();
    let v: Vec<f32> = (0..kn).map(|_| rng.f32_pm1() * 0.1).collect();
    let dn = c.draft.cache_elems(cap);
    let dk: Vec<f32> = (0..dn).map(|_| rng.f32_pm1() * 0.1).collect();
    let dv: Vec<f32> = (0..dn).map(|_| rng.f32_pm1() * 0.1).collect();
    let t = 256;

    println!("== teacher verification per S variant, fused vs eager ==");
    for s in [8usize, 16, 32, 64, 128] {
        let tokens: Vec<i32> = (0..s).map(|_| rng.range(2, 512) as i32).collect();
        let positions: Vec<i32> = (0..s).map(|i| (t + i) as i32).collect();
        let w = cap + s;
        let mut mask = vec![NEG_INF; s * w];
        for i in 0..s {
            mask[i * w..i * w + t].fill(0.0);
            for j in 0..=i {
                mask[i * w + cap + j] = 0.0;
            }
        }
        let mut out = StepScratch::new();
        for mode in [ExecMode::Fused, ExecMode::Eager] {
            bench(&format!("teacher_{}_s{s}", mode.as_str()), 200.0, 5, || {
                backend
                    .teacher_step(mode, StepArgs {
                        tokens: &tokens,
                        positions: &positions,
                        mask: &mask,
                        kv: KvView::flat(&k, &v, cap),
                        feats_in: None,
                        probe: false,
                        session: None,
                    }, &mut out)
                    .unwrap();
                black_box(out.logits[0]);
            });
        }
    }

    println!("== draft step per S variant ==");
    for s in [8usize, 32, 64] {
        let tokens: Vec<i32> = (0..s).map(|_| rng.range(2, 512) as i32).collect();
        let positions: Vec<i32> = (0..s).map(|i| (t + i) as i32).collect();
        let feats = vec![0.05f32; s * c.feat_dim];
        let w = cap + s;
        let mut mask = vec![NEG_INF; s * w];
        for i in 0..s {
            mask[i * w..i * w + t].fill(0.0);
            mask[i * w + cap + i] = 0.0;
        }
        let mut out = StepScratch::new();
        bench(&format!("draft_s{s}"), 200.0, 5, || {
            backend
                .draft_step(StepArgs {
                    tokens: &tokens,
                    positions: &positions,
                    mask: &mask,
                    kv: KvView::flat(&dk, &dv, cap),
                    feats_in: Some(&feats),
                    probe: false,
                    session: None,
                }, &mut out)
                .unwrap();
            black_box(out.logits[0]);
        });
    }
}
