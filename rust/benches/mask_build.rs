//! Mask-construction micro-bench (paper §3.3 implementation note):
//! dense ancestor-walk builder vs ancestor-table/bitset builder across
//! speculative budgets — the paper's "dense vs structured masks"
//! trade-off, plus the chain-mask fast path used by prefill/baseline.

use eagle_pangu::tree::{MaskBuilder, SpecTree, Tensorized};
use eagle_pangu::util::bench::{bench, black_box};
use eagle_pangu::util::SplitMix64;

fn random_tree(budget: usize, seed: u64) -> SpecTree {
    let mut rng = SplitMix64::new(seed);
    let mut tree = SpecTree::with_root(5);
    let mut frontier = vec![0usize];
    let mut added = 0;
    while added < budget && !frontier.is_empty() {
        let mut next = Vec::new();
        for &p in &frontier {
            for _ in 0..rng.range(1, 5) {
                if added >= budget {
                    break;
                }
                next.push(tree.add_child(p, rng.range(2, 512) as i32, -0.5));
                added += 1;
            }
        }
        frontier = next;
    }
    tree
}

fn main() {
    println!("== mask construction: dense vs ancestor-table (paper §3.3) ==");
    let cap = 512;
    let mb = MaskBuilder::new(cap);
    let t = 384; // committed prefix length
    for (m, s_pad) in [(15, 16usize), (63, 64), (127, 128), (255, 256)] {
        let tens = Tensorized::from_tree(&random_tree(m, 7), s_pad, true).unwrap();
        let mut buf = Vec::new();
        bench(&format!("mask_dense_m{m}_s{s_pad}"), 25.0, 7, || {
            mb.build_dense(&mut buf, &tens, t, None);
            black_box(buf.len());
        });
        bench(&format!("mask_table_m{m}_s{s_pad}"), 25.0, 7, || {
            mb.build_table(&mut buf, &tens, t, None);
            black_box(buf.len());
        });
    }
    let mut buf = Vec::new();
    bench("mask_chain_s8_prefill_row", 25.0, 7, || {
        mb.build_chain(&mut buf, 8, 1, t, None);
        black_box(buf.len());
    });
    bench("mask_chain_s128_prefill_chunk", 25.0, 7, || {
        mb.build_chain(&mut buf, 128, 128, t, None);
        black_box(buf.len());
    });
}
