//! Mask-construction micro-bench (paper §3.3 implementation note):
//! dense ancestor-walk builder vs ancestor-table/bitset builder vs the
//! incremental builder across speculative budgets — the paper's "dense vs
//! structured masks" trade-off plus this repo's `O(S*Δt + S*S)`
//! incremental path — and the chain-mask fast path used by
//! prefill/baseline, full vs incremental.

use eagle_pangu::tree::{MaskBuilder, MaskStream, SpecTree, Tensorized};
use eagle_pangu::util::bench::{bench, black_box};
use eagle_pangu::util::SplitMix64;

fn random_tree(budget: usize, seed: u64) -> SpecTree {
    let mut rng = SplitMix64::new(seed);
    let mut tree = SpecTree::with_root(5);
    let mut frontier = vec![0usize];
    let mut added = 0;
    while added < budget && !frontier.is_empty() {
        let mut next = Vec::new();
        for &p in &frontier {
            for _ in 0..rng.range(1, 5) {
                if added >= budget {
                    break;
                }
                next.push(tree.add_child(p, rng.range(2, 512) as i32, -0.5));
                added += 1;
            }
        }
        frontier = next;
    }
    tree
}

fn main() {
    println!("== mask construction: dense vs ancestor-table vs incremental (paper §3.3) ==");
    let cap = 512;
    let mut mb = MaskBuilder::new(cap);
    let t = 384; // committed prefix length
    for (m, s_pad) in [(15, 16usize), (63, 64), (127, 128), (255, 256)] {
        let tens = Tensorized::from_tree(&random_tree(m, 7), s_pad, true).unwrap();
        let mut buf = Vec::new();
        bench(&format!("mask_dense_m{m}_s{s_pad}"), 25.0, 7, || {
            mb.build_dense(&mut buf, &tens, t, None);
            black_box(buf.len());
        });
        bench(&format!("mask_table_m{m}_s{s_pad}"), 25.0, 7, || {
            mb.build_table(&mut buf, &tens, t, None);
            black_box(buf.len());
        });
        // steady state: prefix unchanged between rounds (Δt amortized by
        // the growing-prefix sweep below), spec block rewritten
        bench(&format!("mask_incr_steady_m{m}_s{s_pad}"), 25.0, 7, || {
            let inc = mb.tree_incremental(MaskStream::TeacherTree, &tens, t, None);
            black_box(inc.len());
        });
    }

    println!("== growing-prefix sweep: full rebuild vs incremental delta (Δt=3/round) ==");
    for (m, s_pad) in [(15, 16usize), (63, 64), (127, 128), (255, 256)] {
        let tens = Tensorized::from_tree(&random_tree(m, 11), s_pad, true).unwrap();
        let mut buf = Vec::new();
        let mut t_full = 0usize;
        bench(&format!("mask_full_grow_m{m}"), 25.0, 7, || {
            t_full = if t_full + 3 >= cap { 0 } else { t_full + 3 };
            mb.build_auto(&mut buf, &tens, t_full, None);
            black_box(buf.len());
        });
        let mut t_inc = 0usize;
        bench(&format!("mask_incr_grow_m{m}"), 25.0, 7, || {
            t_inc = if t_inc + 3 >= cap { 0 } else { t_inc + 3 };
            let inc = mb.tree_incremental(MaskStream::TeacherTree, &tens, t_inc, None);
            black_box(inc.len());
        });
    }

    println!("== chain masks (prefill/baseline/draft refresh) ==");
    let mut buf = Vec::new();
    bench("mask_chain_s8_prefill_row", 25.0, 7, || {
        mb.build_chain(&mut buf, 8, 1, t, None);
        black_box(buf.len());
    });
    bench("mask_chain_s128_prefill_chunk", 25.0, 7, || {
        mb.build_chain(&mut buf, 128, 128, t, None);
        black_box(buf.len());
    });
    let mut t_chain = 0usize;
    bench("mask_chain_incr_s8_decode_step", 25.0, 7, || {
        t_chain = if t_chain + 1 >= cap { 0 } else { t_chain + 1 };
        let inc = mb.chain_incremental(MaskStream::TeacherChain, 8, 1, t_chain, None);
        black_box(inc.len());
    });
}
