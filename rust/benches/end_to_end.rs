//! End-to-end decode bench: one full turn, baseline vs EA, on the real
//! artifacts when present (else the SimBackend). This is the per-turn
//! version of E1 — `eagle-pangu bench-e1` regenerates the full Table 1.

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::config::{CacheStrategy, RunConfig};
use eagle_pangu::engine::Engine;
use eagle_pangu::runtime::PjrtBackend;
use eagle_pangu::util::bench::{bench, black_box};
use eagle_pangu::workload::Grammar;

fn backend() -> Box<dyn ModelBackend> {
    match PjrtBackend::load("artifacts") {
        Ok(b) => Box::new(b),
        Err(_) => {
            eprintln!("note: artifacts/ missing, benching the SimBackend");
            Box::new(SimBackend::new(85))
        }
    }
}

fn main() {
    let prompt = Grammar::code().sample_sequence(48, 3, None);
    let max_new = 48;

    let mut b = backend();
    let cfg = RunConfig::default();
    let mut engine = Engine::new(&mut *b, cfg.clone());
    bench("turn_baseline_48tok", 500.0, 3, || {
        engine.reset();
        let out = engine.generate_baseline(&prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });

    bench("turn_ea_m16_d10", 500.0, 3, || {
        engine.reset();
        let out = engine.generate_speculative(&prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });

    let mut cfg2 = cfg.clone();
    cfg2.tree.budget = 8;
    cfg2.tree.depth_max = 5;
    let mut b2 = backend();
    let mut engine2 = Engine::new(&mut *b2, cfg2);
    bench("turn_ea_m8_d5", 500.0, 3, || {
        engine2.reset();
        let out = engine2.generate_speculative(&prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });

    let mut cfg3 = cfg;
    cfg3.cache_strategy = CacheStrategy::DeepCopy;
    let mut b3 = backend();
    let mut engine3 = Engine::new(&mut *b3, cfg3);
    bench("turn_ea_m16_deepcopy", 500.0, 3, || {
        engine3.reset();
        let out = engine3.generate_speculative(&prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });
}
