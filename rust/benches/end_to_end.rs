//! End-to-end decode bench: one full turn, baseline vs EA, on the real
//! artifacts when present (else the SimBackend). This is the per-turn
//! version of E1 — `eagle-pangu bench-e1` regenerates the full Table 1.
//!
//! Also emits `BENCH_hotpath.json` — machine-readable rounds/sec,
//! tokens/sec and bytes-allocated/round for the EA steady state, plus the
//! cross-request batching sweep (B in {1, 2, 4, 8}) — so the perf
//! trajectory of the hot path is tracked across PRs (compare against the
//! previous PR's file).
//!
//! # Batching sweep methodology
//!
//! The sweep decodes the same 8-conversation workload under scheduler
//! fusion widths B in {1, 2, 4, 8} (B = 1 is the sequential baseline:
//! every request verified in its own launch) and reports aggregate
//! request-rounds per second. It runs on the SimBackend with the
//! **teacher launch-cost model** enabled (1.5 ms spin per teacher
//! launch): on real accelerators the fixed host-dispatch + kernel-launch
//! latency of the fused teacher module is the quantity cross-request
//! batching amortizes, and the sim's compute is otherwise too cheap to
//! expose it. The model is applied identically at every B (including the
//! B = 1 baseline), so the reported speedup measures launch amortization
//! only — tokens decoded are bit-identical across B by the batching
//! contract. `launch_cost_us` is recorded in the JSON so the number is
//! reproducible and honest.
//!
//! # Straggler workload (continuous vs fixed grouping)
//!
//! The `straggler` entry decodes a ragged 16-conversation workload
//! (twelve 2-token stragglers, four 48-token long turns) on 8 slots two
//! ways: **fixed grouping** (chunks of 8 admitted together; each chunk
//! drains to narrow launches while its long turns finish — the PR-2
//! protocol) and **continuous admission** (retired conversations free
//! their slot for the next queued one at the same tick, sustaining
//! full-width launches). Tokens are bit-identical; only launch counts
//! and wall-clock differ. The launch-cost model adds a small per-row
//! compute charge (`row_cost_ns`) so the reported speedup cannot pretend
//! row compute is amortizable — it measures launch amortization plus
//! slot utilization only. `straggler_continuous_speedup` is gated in CI
//! (`bench_gate`): continuous admission must keep beating fixed grouping.
//! The straggler workload runs under the **paged** cache layout so the
//! gated speedup covers block-table caches on the serving hot path.
//!
//! # Pipelining sweep (`pipeline`)
//!
//! The `pipeline` entry A/Bs the software-pipelined serve loop
//! (`--pipelining on`, the default: double-buffered half-ticks that
//! overlap draft expansion with the in-flight fused launch) against the
//! synchronous reference at B in {4, 8}. The cost model gives both
//! halves weight — a 2 ms teacher launch, 5 us/row compute, and a
//! 150 us host-side draft dispatch — so the sweep measures real overlap,
//! not a degenerate regime. The batch and straggler sweeps above pin
//! the synchronous loop (their baselines predate pipelining and they
//! measure fusion-width amortization, which halved pipelined waves
//! would conflate). `pipeline_speedup_b8` is pinned in the baseline and
//! gated `>= 1.0` by `bench_gate`; the B=4 point is tracked unpinned.
//!
//! # KV memory occupancy (`kv_resident`)
//!
//! A timing-free section decodes B ∈ {1, 2, 4, 8} resident conversations
//! under both cache layouts and records the summed per-slot
//! `kv_bytes_resident` (flat: pinned full-capacity buffers; paged:
//! mapped blocks only). These bytes are machine-independent, so the CI
//! gate holds them tight: paged must never exceed flat at B >= 4, and a
//! paged-occupancy regression beyond 15% of the pinned baseline fails.
//!
//! # Trace-replay latency distribution (`latency`)
//!
//! A timing-free section replays seeded Poisson and bursty arrival
//! traces (48 mixed code/chat requests) through the continuous
//! scheduler at B in {4, 8} under the virtual device-clock model of
//! `harness::replay`, and records p50/p95/p99 completion latency plus
//! the shed rate. Virtual clocks make the percentiles bit-identical
//! across machines, so `bench_gate` holds a *hard* p99 SLO floor
//! (`latency.slo_ms`) on them — the paper's headline metric is a p99
//! speedup, and this is the regression tripwire for it. An `overload_*`
//! point replays a 10x-sustainable rate with a shed-action SLO so the
//! deterministic shed rate of SLO admission is gated against creep.
//!
//! # CoW prefix sharing (`sharing`)
//!
//! A timing-free section runs the shared-prefix workload (8
//! conversations extending one 160-token system prompt) through a
//! 4-slot continuous scheduler with `--prefix-sharing` off and on,
//! parking every retired conversation, and records prefill
//! teacher-calls per admitted conversation plus the pools' referenced
//! KV bytes at full residency. Both numbers are machine-independent;
//! `bench_gate` requires sharing-on to beat sharing-off on both.
//!
//! # Multi-worker sharding (`multiworker`)
//!
//! The `multiworker` section replays the latency section's Poisson
//! trace through the coordinator/worker split at worker counts
//! {1, 2, 4} (4 slots per worker; `harness::replay` routes every replay
//! through a `Coordinator`, so workers = 1 exercises the same channel
//! RPC). The p99 percentiles run on each worker's virtual clock and are
//! bit-identical across machines; `bench_gate` holds workers=4 p99
//! `<=` workers=1 p99 — sharding a fixed arrival rate across more
//! workers must never inflate the tail. Fused rounds per wall-clock
//! second (summed across ranks) is recorded alongside but tracked
//! unpinned: it carries real channel and thread overhead and is
//! machine-dependent.

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::cache::CachePools;
use eagle_pangu::config::{CacheLayout, CacheStrategy, RunConfig};
use eagle_pangu::coordinator::{
    decode_speculative_batch, Completion, ContinuousScheduler, Disposition, SloAction,
    SloPolicy, SlotRequest,
};
use eagle_pangu::engine::Engine;
use eagle_pangu::harness::{replay, ReplayConfig};
use eagle_pangu::json::Json;
use eagle_pangu::runtime::PjrtBackend;
use eagle_pangu::util::bench::{bench, black_box};
use eagle_pangu::workload::{ArrivalKind, Grammar, PromptFamily, SharedPrefixSpec, TraceSpec};
use std::time::{Duration, Instant};

// Shared with tests/alloc_regression.rs by path: the counting
// allocator's `unsafe impl GlobalAlloc` cannot live in the library
// (crate-root `#![forbid(unsafe_code)]`), and the counting rule must
// not drift between the bench and the regression test.
#[path = "../tests/support/alloc_count.rs"]
mod alloc_count;
use alloc_count::CountingAlloc;

// # KV-session upload traffic (`upload`)
//
// A second timing-free section decodes a steady-state turn at B in
// {1, 4} with KV sessions on vs off and records the sim's modeled
// host->device `upload_bytes` per committed token. These bytes are
// deterministic; `bench_gate` requires the session-on path to ship
// <= 0.25x the session-off path at B >= 4 (the resident-session
// contract: steady-state transfer must not scale with the cache
// capacity).

// Count every allocation (threshold 0): the bytes-allocated/round series
// in BENCH_hotpath.json.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new(0);

fn backend() -> Box<dyn ModelBackend> {
    match PjrtBackend::load("artifacts") {
        Ok(b) => Box::new(b),
        Err(_) => {
            eprintln!("note: artifacts/ missing, benching the SimBackend");
            Box::new(SimBackend::new(85))
        }
    }
}

fn main() {
    let prompt = Grammar::code().sample_sequence(48, 3, None);
    let max_new = 48;

    let mut b = backend();
    let backend_name = b.name();
    let cfg = RunConfig::default();
    let mut engine = Engine::new(&*b, cfg.clone());
    engine.warmup(&mut *b).unwrap();
    bench("turn_baseline_48tok", 500.0, 3, || {
        engine.reset();
        let out = engine.generate_baseline(&mut *b, &prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });

    bench("turn_ea_m16_d10", 500.0, 3, || {
        engine.reset();
        let out = engine.generate_speculative(&mut *b, &prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });

    // ---- hot-path steady-state measurement (machine-readable) ----
    // Warm every buffer to its high-water mark, then measure a sustained
    // run: rounds/sec, tokens/sec and allocator traffic per round.
    engine.reset();
    engine.generate_speculative(&mut *b, &prompt, max_new).unwrap();
    engine.reset();
    let bytes0 = ALLOC.bytes();
    let calls0 = ALLOC.allocs();
    let t0 = Instant::now();
    let mut rounds = 0u64;
    let mut tokens = 0u64;
    let mut turns = 0u64;
    while t0.elapsed().as_secs_f64() < 2.0 {
        engine.reset();
        let out = engine.generate_speculative(&mut *b, &prompt, max_new).unwrap();
        rounds += out.rounds;
        tokens += out.tokens.len() as u64;
        turns += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    let bytes = ALLOC.bytes() - bytes0;
    let calls = ALLOC.allocs() - calls0;
    let rounds_per_sec = rounds as f64 / secs;
    let tokens_per_sec = tokens as f64 / secs;
    let bytes_per_round = bytes as f64 / rounds.max(1) as f64;
    let allocs_per_round = calls as f64 / rounds.max(1) as f64;
    println!(
        "hotpath: {rounds_per_sec:.0} rounds/s  {tokens_per_sec:.0} tok/s  \
         {bytes_per_round:.0} B alloc/round  {allocs_per_round:.1} allocs/round \
         ({turns} turns)"
    );

    // ---- cross-request batching sweep (sim + launch-cost model) ----
    let launch_cost_us: u64 = 1500;
    let sweep_convs = 8usize;
    let sweep_max_new = 24usize;
    let sweep_prompts: Vec<Vec<i32>> = (0..sweep_convs)
        .map(|i| Grammar::code().sample_sequence(32, 100 + i as u64, None))
        .collect();
    let mut batch_json = Json::obj();
    let mut rps_b1 = 0.0f64;
    let mut rps_b4 = 0.0f64;
    for bsz in [1usize, 2, 4, 8] {
        let mut sim = SimBackend::new(85)
            .with_teacher_launch(Duration::from_micros(launch_cost_us));
        let mut engines: Vec<Engine> =
            (0..sweep_convs).map(|_| Engine::new(&sim, cfg.clone())).collect();
        for e in engines.iter_mut() {
            e.warmup(&mut sim).unwrap();
        }
        let cap = sim.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(bsz, cap);
        // synchronous serve loop: this sweep isolates *launch
        // amortization by fusion width* — the pipelined loop halves
        // steady wave widths and would conflate the two effects (the
        // pipeline sweep below measures the overlapped loop on its own)
        sched.set_pipelining(false);
        // warm drive (fused staging to high-water), then timed drives
        decode_speculative_batch(&mut sim, &mut engines, &sweep_prompts, sweep_max_new,
                                 &mut sched)
            .unwrap();
        let t0 = Instant::now();
        let mut sweep_rounds = 0u64;
        let mut iters = 0u64;
        while t0.elapsed().as_secs_f64() < 1.5 {
            for e in engines.iter_mut() {
                e.reset();
            }
            let outs = decode_speculative_batch(
                &mut sim, &mut engines, &sweep_prompts, sweep_max_new, &mut sched)
                .unwrap();
            sweep_rounds += outs.iter().map(|o| o.rounds).sum::<u64>();
            iters += 1;
        }
        let rps = sweep_rounds as f64 / t0.elapsed().as_secs_f64();
        if bsz == 1 {
            rps_b1 = rps;
        }
        if bsz == 4 {
            rps_b4 = rps;
        }
        println!(
            "batch sweep B={bsz}: {rps:.0} request-rounds/s \
             ({} launches, {iters} sweeps)",
            sim.teacher_calls
        );
        batch_json.push(&format!("B{bsz}_rounds_per_sec"), rps);
    }
    let b4_speedup = if rps_b1 > 0.0 { rps_b4 / rps_b1 } else { 0.0 };
    println!("batch sweep: B=4 speedup over sequential B=1: {b4_speedup:.2}x");

    // ---- pipelining sweep: overlapped vs synchronous serve loop ----
    // A/B the software-pipelined serve loop (`--pipelining on`, the
    // default) against the synchronous reference at B in {4, 8} under a
    // cost model where both halves of the overlap matter: a 2 ms teacher
    // launch (the device window the host can hide work in), a 5 us/row
    // compute charge, and a 150 us *host-side* draft dispatch cost (the
    // work the flight hides — drafting makes several dispatches per
    // round, so per-slot host work lands at 0.5-1 ms). Tokens are
    // bit-identical across the two loops by the pipelining contract;
    // only wall-clock differs. `pipeline_speedup_b8` is gated in CI
    // (`bench_gate`): overlap must never lose to the synchronous loop
    // at full width. The B=4 point is emitted for tracking — at narrow
    // widths the halved steady wave (width 2) gives back launch
    // amortization, so its margin is structurally thinner.
    let pipe_launch_us: u64 = 2_000;
    let pipe_row_ns: u64 = 5_000;
    let pipe_draft_us: u64 = 150;
    let mut pipe_json = Json::obj();
    let mut pipe_speedup_b4 = 0.0f64;
    let mut pipe_speedup_b8 = 0.0f64;
    for bsz in [4usize, 8] {
        let mut rps_modes = [0.0f64; 2]; // [synchronous, pipelined]
        for (mi, pipelining) in [false, true].into_iter().enumerate() {
            let mut sim = SimBackend::new(85)
                .with_teacher_launch(Duration::from_micros(pipe_launch_us))
                .with_row_cost(Duration::from_nanos(pipe_row_ns))
                .with_draft_cost(Duration::from_micros(pipe_draft_us));
            let mut engines: Vec<Engine> =
                (0..bsz).map(|_| Engine::new(&sim, cfg.clone())).collect();
            for e in engines.iter_mut() {
                e.warmup(&mut sim).unwrap();
            }
            let cap = sim.contract().cache_cap;
            let mut sched = ContinuousScheduler::new(bsz, cap);
            sched.set_pipelining(pipelining);
            // warm drive (sizes both ping-pong staging buffers), then
            // timed drives
            decode_speculative_batch(
                &mut sim, &mut engines, &sweep_prompts[..bsz], sweep_max_new, &mut sched)
                .unwrap();
            let t0 = Instant::now();
            let mut pipe_rounds = 0u64;
            while t0.elapsed().as_secs_f64() < 1.5 {
                for e in engines.iter_mut() {
                    e.reset();
                }
                let outs = decode_speculative_batch(
                    &mut sim, &mut engines, &sweep_prompts[..bsz], sweep_max_new, &mut sched)
                    .unwrap();
                pipe_rounds += outs.iter().map(|o| o.rounds).sum::<u64>();
            }
            rps_modes[mi] = pipe_rounds as f64 / t0.elapsed().as_secs_f64();
            let tag = if pipelining { "pipelined" } else { "synchronous" };
            println!(
                "pipeline sweep B={bsz} {tag}: {:.0} request-rounds/s \
                 (overlap saved {:.1} ms)",
                rps_modes[mi],
                sim.overlap_saved_secs * 1e3
            );
            pipe_json.push(&format!("{tag}_b{bsz}_rounds_per_sec"), rps_modes[mi]);
        }
        let speedup = if rps_modes[0] > 0.0 { rps_modes[1] / rps_modes[0] } else { 0.0 };
        println!("pipeline sweep B={bsz}: pipelined speedup over synchronous: {speedup:.2}x");
        if bsz == 4 {
            pipe_speedup_b4 = speedup;
        } else {
            pipe_speedup_b8 = speedup;
        }
    }
    pipe_json
        .push("launch_cost_us", pipe_launch_us)
        .push("row_cost_ns", pipe_row_ns)
        .push("draft_cost_us", pipe_draft_us);

    // ---- KV memory occupancy: flat vs paged, B resident slots ----
    // Deterministic (no timing): decode the sweep workload's first B
    // conversations to completion on B resident slots under each layout,
    // then sum per-slot `kv_bytes_resident`. Flat pins full-capacity
    // buffers per slot; paged maps blocks for the committed context only.
    // The CI memory gate (`bench_gate`) requires paged <= flat at B >= 4
    // and bounds paged regressions against the pinned baseline.
    let mut kv_json = Json::obj();
    for layout in [CacheLayout::Flat, CacheLayout::Paged] {
        for bsz in [1usize, 2, 4, 8] {
            let mut sim = SimBackend::new(85);
            let mut lcfg = cfg.clone();
            lcfg.cache_layout = layout;
            let pools = CachePools::new(sim.contract());
            let mut engines: Vec<Engine> = (0..bsz)
                .map(|_| Engine::with_pools(&sim, lcfg.clone(), &pools))
                .collect();
            let cap = sim.contract().cache_cap;
            let mut sched = ContinuousScheduler::new(bsz, cap);
            decode_speculative_batch(
                &mut sim, &mut engines, &sweep_prompts[..bsz], sweep_max_new, &mut sched)
                .unwrap();
            let resident: u64 = engines.iter().map(Engine::kv_bytes_resident).sum();
            println!(
                "kv resident {} B={bsz}: {resident} bytes ({} per conversation)",
                layout.as_str(),
                resident / bsz as u64
            );
            kv_json.push(
                &format!("{}_b{bsz}_kv_bytes_resident", layout.as_str()),
                resident as f64,
            );
        }
    }

    // ---- KV-session upload traffic: session-on vs session-off ----
    // Deterministic bytes from the sim's host->device transfer model:
    // without sessions every step re-ships the full [L, cap, H, Dh]
    // cache pair; with sessions (default) each conversation cache is
    // bound once and steps ship only dirty-row deltas. Steady state is
    // the second turn of resident conversations (bind cost excluded —
    // it is an admission-boundary cost, not a per-step one). The CI
    // gate requires the resident-session path to upload <= 0.25x the
    // full-upload path at B >= 4.
    let mut upload_json = Json::obj();
    for bsz in [1usize, 4] {
        for sessions in [true, false] {
            let mut sim = SimBackend::new(85);
            let mut ucfg = cfg.clone();
            ucfg.kv_sessions = sessions;
            let pools = CachePools::new(sim.contract());
            let mut engines: Vec<Engine> = (0..bsz)
                .map(|_| Engine::with_pools(&sim, ucfg.clone(), &pools))
                .collect();
            let cap = sim.contract().cache_cap;
            let mut sched = ContinuousScheduler::new(bsz, cap);
            // warm turn: binds sessions, sizes every buffer
            decode_speculative_batch(
                &mut sim, &mut engines, &sweep_prompts[..bsz], sweep_max_new, &mut sched)
                .unwrap();
            // steady state: continue the same resident conversations
            let cont: Vec<Vec<i32>> = (0..bsz)
                .map(|i| Grammar::code().sample_sequence(2, 900 + i as u64, None))
                .collect();
            let snap = sim.upload_bytes;
            let outs = decode_speculative_batch(
                &mut sim, &mut engines, &cont, sweep_max_new, &mut sched)
                .unwrap();
            let toks: u64 = outs.iter().map(|o| o.tokens.len() as u64).sum();
            let per_tok = (sim.upload_bytes - snap) as f64 / toks.max(1) as f64;
            let tag = if sessions { "session_on" } else { "session_off" };
            println!("upload {tag} B={bsz}: {per_tok:.0} B/token");
            upload_json.push(&format!("{tag}_b{bsz}_upload_bytes_per_token"), per_tok);
        }
    }

    // ---- straggler workload: continuous admission vs fixed grouping ----
    // Runs under the PAGED layout: the gated `straggler_continuous_speedup`
    // must stay a win with block-table caches on the serving hot path
    // (the flat-layout number is tracked by the batch sweep above).
    let row_cost_ns: u64 = 2_000;
    let strag_convs = 16usize;
    let strag_slots = 8usize;
    let strag_prompts: Vec<Vec<i32>> = (0..strag_convs)
        .map(|i| Grammar::code().sample_sequence(24, 300 + i as u64, None))
        .collect();
    // 3:1 stragglers to long turns — each fixed chunk of 8 holds two
    // long turns that drain it to width-2 launches
    let strag_max_new = |i: usize| if i % 4 == 3 { 48 } else { 2 };
    let mut strag_json = Json::obj();
    let mut rps_fixed = 0.0f64;
    let mut rps_cont = 0.0f64;
    let mut strag_cfg = cfg.clone();
    strag_cfg.cache_layout = CacheLayout::Paged;
    for continuous in [false, true] {
        let mut sim = SimBackend::new(85)
            .with_teacher_launch(Duration::from_micros(launch_cost_us))
            .with_row_cost(Duration::from_nanos(row_cost_ns));
        let pools = CachePools::new(sim.contract());
        let mut engines: Vec<Engine> = (0..strag_slots)
            .map(|_| Engine::with_pools(&sim, strag_cfg.clone(), &pools))
            .collect();
        for e in engines.iter_mut() {
            e.warmup(&mut sim).unwrap();
        }
        let cap = sim.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(strag_slots, cap);
        // synchronous serve loop on both sides: the gated speedup
        // measures continuous admission vs fixed grouping, and its
        // pinned baseline was measured synchronously (the pipelining
        // axis has its own gated sweep above)
        sched.set_pipelining(false);
        // fixed grouping = admit in chunks of `slots` and drain each
        // chunk; continuous = one queue over all conversations
        let admit_chunk = if continuous { strag_convs } else { strag_slots };
        let ids: Vec<usize> = (0..strag_convs).collect();
        let run_pass = |sim: &mut SimBackend,
                            engines: &mut Vec<Engine>,
                            sched: &mut ContinuousScheduler|
         -> u64 {
            let mut pass_rounds = 0u64;
            for chunk in ids.chunks(admit_chunk) {
                for &i in chunk {
                    sched.submit(SlotRequest {
                        id: i as u64,
                        prompt: strag_prompts[i].clone(),
                        max_new: strag_max_new(i),
                        cfg: None,
                        slo: None,
                    });
                }
                sched
                    .run_to_idle(&mut *sim, &mut engines[..], &mut |c: Completion| {
                        pass_rounds += c.out.rounds;
                        Disposition::Release
                    })
                    .unwrap();
            }
            pass_rounds
        };
        // warm pass: sizes every buffer AND measures launches per pass
        let launches_before = sim.teacher_calls;
        run_pass(&mut sim, &mut engines, &mut sched);
        let launches_per_pass = sim.teacher_calls - launches_before;
        let t0 = Instant::now();
        let mut strag_rounds = 0u64;
        while t0.elapsed().as_secs_f64() < 1.5 {
            strag_rounds += run_pass(&mut sim, &mut engines, &mut sched);
        }
        let rps = strag_rounds as f64 / t0.elapsed().as_secs_f64();
        let tag = if continuous { "continuous" } else { "fixed" };
        if continuous {
            rps_cont = rps;
        } else {
            rps_fixed = rps;
        }
        println!(
            "straggler B={strag_slots} {tag}: {rps:.0} request-rounds/s \
             ({launches_per_pass} launches/pass)"
        );
        strag_json
            .push(&format!("{tag}_b8_rounds_per_sec"), rps)
            .push(&format!("{tag}_launches_per_pass"), launches_per_pass);
    }
    let strag_speedup = if rps_fixed > 0.0 { rps_cont / rps_fixed } else { 0.0 };
    println!("straggler: continuous admission speedup over fixed grouping: {strag_speedup:.2}x");
    strag_json.push("row_cost_ns", row_cost_ns);
    strag_json.push("cache_layout", strag_cfg.cache_layout.as_str());

    // ---- CoW prefix sharing: prefill work + KV residency ----
    // Deterministic (no timing): the shared-prefix workload (8
    // conversations extending one 160-token system prompt) runs through
    // a 4-slot continuous scheduler with `--prefix-sharing` off and on,
    // parking every retired conversation so the final residency is the
    // full resident set — the serving regime prefix sharing targets.
    // Two metrics per side: prefill teacher-calls per admitted
    // conversation (sharing-on admissions adopt the resident frozen run
    // and skip its prefill launches) and the pools' referenced KV bytes
    // with all conversations parked (shared blocks count once). Both are
    // machine-independent; `bench_gate` requires sharing-on to beat
    // sharing-off on both at B = 4, and tokens are bit-identical by the
    // CoW contract (enforced by `tests/prefix_sharing.rs`).
    let share_spec = SharedPrefixSpec::default();
    let share_prompts = share_spec.prompts();
    let share_slots = 4usize;
    let mut share_json = Json::obj();
    let mut share_metrics = [[0.0f64; 2]; 2]; // [off, on] x [calls/conv, bytes]
    for (si, sharing) in [false, true].into_iter().enumerate() {
        let mut sim = SimBackend::new(85);
        let mut scfg = cfg.clone();
        scfg.cache_layout = CacheLayout::Paged;
        scfg.prefix_sharing = sharing;
        let pools = CachePools::new(sim.contract());
        let mut engines: Vec<Engine> = (0..share_slots)
            .map(|_| Engine::with_pools(&sim, scfg.clone(), &pools))
            .collect();
        let cap = sim.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(share_slots, cap);
        for (i, p) in share_prompts.iter().enumerate() {
            sched.submit(SlotRequest {
                id: i as u64,
                prompt: p.clone(),
                max_new: 8,
                cfg: None,
                slo: None,
            });
        }
        sched
            .run_to_idle(&mut sim, &mut engines, &mut |_c: Completion| Disposition::Park)
            .unwrap();
        let admitted = share_prompts.len() as f64;
        let calls_per_conv = sched.stats.prefill_teacher_calls as f64 / admitted;
        let resident = pools.referenced_bytes() as f64;
        let tag = if sharing { "sharing_on" } else { "sharing_off" };
        println!(
            "prefix sharing B={share_slots} {tag}: {calls_per_conv:.2} prefill \
             teacher-calls/conv, {resident:.0} KV bytes resident ({admitted} parked)"
        );
        share_json
            .push(&format!("{tag}_b4_prefill_teacher_calls_per_conv"), calls_per_conv)
            .push(&format!("{tag}_b4_kv_bytes_resident"), resident);
        share_metrics[si] = [calls_per_conv, resident];
    }
    share_json
        .push("conversations", share_spec.conversations)
        .push("prefix_len", share_spec.prefix_len);
    println!(
        "prefix sharing: prefill calls/conv {:.2} -> {:.2}, resident bytes {:.0} -> {:.0}",
        share_metrics[0][0], share_metrics[1][0], share_metrics[0][1], share_metrics[1][1]
    );

    // ---- trace-replay latency distribution (deterministic) ----
    // Replays seeded Poisson and bursty arrival traces through the
    // continuous scheduler under the virtual device-clock model
    // (harness::replay): per-tick host cost + per-fused-launch device
    // cost, no wall-clock reads. The emitted p50/p95/p99 are therefore
    // bit-identical run to run and machine to machine, which is what
    // lets `bench_gate` hold a hard p99 SLO floor (`latency.slo_ms`)
    // without flaking — the paper's headline metric is a p99 speedup.
    // The `overload_*` point replays a 10x-sustainable arrival rate with
    // a shed-action SLO attached, so the deterministic shed rate of the
    // admission layer is tracked too (gated against creep).
    let latency_slo_ms = 250.0f64;
    let lat_spec = |kind: ArrivalKind| TraceSpec {
        requests: 48,
        kind,
        family: PromptFamily::Mixed,
        prompt_mean: 16,
        max_new: 6,
        seed: 11,
    };
    let mut lat_json = Json::obj();
    for (tag, kind) in [
        ("poisson", ArrivalKind::Poisson { rate_rps: 40.0 }),
        (
            "bursty",
            ArrivalKind::Bursty { rate_lo_rps: 10.0, rate_hi_rps: 120.0, switch_p: 0.25 },
        ),
    ] {
        let trace = lat_spec(kind).generate().unwrap();
        for bsz in [4usize, 8] {
            let rep = replay(&trace, &ReplayConfig::new(bsz)).unwrap();
            println!(
                "latency {tag} B={bsz}: p50 {:.2}  p95 {:.2}  p99 {:.2} virtual ms \
                 ({} completed, shed rate {:.2})",
                rep.p50_ms, rep.p95_ms, rep.p99_ms, rep.completed, rep.shed_rate
            );
            lat_json
                .push(&format!("{tag}_b{bsz}_p50_ms"), rep.p50_ms)
                .push(&format!("{tag}_b{bsz}_p95_ms"), rep.p95_ms)
                .push(&format!("{tag}_b{bsz}_p99_ms"), rep.p99_ms)
                .push(&format!("{tag}_b{bsz}_shed_rate"), rep.shed_rate);
        }
    }
    let overload_target_ms = 30.0f64;
    {
        let trace = lat_spec(ArrivalKind::Poisson { rate_rps: 400.0 }).generate().unwrap();
        let mut rcfg = ReplayConfig::new(4);
        rcfg.slo = Some(SloPolicy { target_ms: overload_target_ms, action: SloAction::Shed });
        let rep = replay(&trace, &rcfg).unwrap();
        println!(
            "latency overload (400 rps, shed @ {overload_target_ms} ms): \
             {} completed, {} shed (shed rate {:.2})",
            rep.completed, rep.shed, rep.shed_rate
        );
        lat_json
            .push("overload_shed_rate", rep.shed_rate)
            .push("overload_target", overload_target_ms);
    }
    lat_json.push("slo_ms", latency_slo_ms);

    // ---- multi-worker serving sweep (deterministic p99) ----
    // Replays the latency section's Poisson trace through the
    // coordinator/worker split (`harness::replay` routes every replay
    // through a Coordinator) at worker counts {1, 2, 4}, 4 slots per
    // worker. The percentiles run on each worker's virtual clock, so
    // they are bit-identical across machines — and `workers1_p99_ms`
    // equals the latency section's `poisson_b4_p99_ms` by construction
    // (one worker over channel RPC replays the identical protocol).
    // `bench_gate` requires workers=4 p99 <= workers=1 p99: sharding a
    // fixed arrival rate across more workers must never inflate the
    // virtual tail. Rounds/sec is wall-clock (fused launches retired
    // per second summed across ranks, channel and thread overhead
    // included) and is tracked unpinned — it is machine-dependent.
    let mut mw_json = Json::obj();
    let mw_trace = lat_spec(ArrivalKind::Poisson { rate_rps: 40.0 }).generate().unwrap();
    for workers in [1usize, 2, 4] {
        let mut rcfg = ReplayConfig::new(4);
        rcfg.workers = workers;
        let t0 = Instant::now();
        let rep = replay(&mw_trace, &rcfg).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let launches: u64 = rep.stats.iter().map(|s| s.fused_launches).sum();
        let mw_rps = launches as f64 / secs.max(1e-9);
        println!(
            "multiworker W={workers}: p99 {:.2} virtual ms, {mw_rps:.0} fused \
             rounds/s wall ({} completed)",
            rep.p99_ms, rep.completed
        );
        mw_json
            .push(&format!("workers{workers}_p99_ms"), rep.p99_ms)
            .push(&format!("workers{workers}_rounds_per_sec"), mw_rps);
    }

    let mut j = Json::obj();
    j.push("bench", "end_to_end_hotpath")
        .push("backend", backend_name)
        .push("mode", engine.cfg.mode.as_str())
        .push("turns", turns)
        .push("rounds", rounds)
        .push("rounds_per_sec", rounds_per_sec)
        .push("tokens_per_sec", tokens_per_sec)
        .push("bytes_allocated_per_round", bytes_per_round)
        .push("allocs_per_round", allocs_per_round)
        .push("batch_sweep", batch_json)
        .push("batch_sweep_launch_cost_us", launch_cost_us)
        .push("batch_sweep_conversations", sweep_convs)
        .push("b4_speedup_vs_b1", b4_speedup)
        .push("pipeline", pipe_json)
        .push("pipeline_speedup_b4", pipe_speedup_b4)
        .push("pipeline_speedup_b8", pipe_speedup_b8)
        .push("kv_resident", kv_json)
        .push("upload", upload_json)
        .push("straggler", strag_json)
        .push("straggler_continuous_speedup", strag_speedup)
        .push("sharing", share_json)
        .push("latency", lat_json)
        .push("multiworker", mw_json);
    std::fs::write("BENCH_hotpath.json", j.to_string_pretty()).unwrap();
    println!("wrote BENCH_hotpath.json");

    let mut cfg2 = cfg.clone();
    cfg2.tree.budget = 8;
    cfg2.tree.depth_max = 5;
    let mut b2 = backend();
    let mut engine2 = Engine::new(&*b2, cfg2);
    engine2.warmup(&mut *b2).unwrap();
    bench("turn_ea_m8_d5", 500.0, 3, || {
        engine2.reset();
        let out = engine2.generate_speculative(&mut *b2, &prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });

    let mut cfg3 = cfg;
    cfg3.cache_strategy = CacheStrategy::DeepCopy;
    let mut b3 = backend();
    let mut engine3 = Engine::new(&*b3, cfg3);
    engine3.warmup(&mut *b3).unwrap();
    bench("turn_ea_m16_deepcopy", 500.0, 3, || {
        engine3.reset();
        let out = engine3.generate_speculative(&mut *b3, &prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });
}
