//! End-to-end decode bench: one full turn, baseline vs EA, on the real
//! artifacts when present (else the SimBackend). This is the per-turn
//! version of E1 — `eagle-pangu bench-e1` regenerates the full Table 1.
//!
//! Also emits `BENCH_hotpath.json` — machine-readable rounds/sec,
//! tokens/sec and bytes-allocated/round for the EA steady state, so the
//! perf trajectory of the hot path is tracked across PRs (compare against
//! the previous PR's file).

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::config::{CacheStrategy, RunConfig};
use eagle_pangu::engine::Engine;
use eagle_pangu::json::Json;
use eagle_pangu::runtime::PjrtBackend;
use eagle_pangu::util::bench::{bench, black_box};
use eagle_pangu::workload::Grammar;
use eagle_pangu::util::alloc_count::CountingAlloc;
use std::time::Instant;

// Count every allocation (threshold 0): the bytes-allocated/round series
// in BENCH_hotpath.json.
#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new(0);

fn backend() -> Box<dyn ModelBackend> {
    match PjrtBackend::load("artifacts") {
        Ok(b) => Box::new(b),
        Err(_) => {
            eprintln!("note: artifacts/ missing, benching the SimBackend");
            Box::new(SimBackend::new(85))
        }
    }
}

fn main() {
    let prompt = Grammar::code().sample_sequence(48, 3, None);
    let max_new = 48;

    let mut b = backend();
    let backend_name = b.name();
    let cfg = RunConfig::default();
    let mut engine = Engine::new(&mut *b, cfg.clone());
    engine.warmup().unwrap();
    bench("turn_baseline_48tok", 500.0, 3, || {
        engine.reset();
        let out = engine.generate_baseline(&prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });

    bench("turn_ea_m16_d10", 500.0, 3, || {
        engine.reset();
        let out = engine.generate_speculative(&prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });

    // ---- hot-path steady-state measurement (machine-readable) ----
    // Warm every buffer to its high-water mark, then measure a sustained
    // run: rounds/sec, tokens/sec and allocator traffic per round.
    engine.reset();
    engine.generate_speculative(&prompt, max_new).unwrap();
    engine.reset();
    let bytes0 = ALLOC.bytes();
    let calls0 = ALLOC.allocs();
    let t0 = Instant::now();
    let mut rounds = 0u64;
    let mut tokens = 0u64;
    let mut turns = 0u64;
    while t0.elapsed().as_secs_f64() < 2.0 {
        engine.reset();
        let out = engine.generate_speculative(&prompt, max_new).unwrap();
        rounds += out.rounds;
        tokens += out.tokens.len() as u64;
        turns += 1;
    }
    let secs = t0.elapsed().as_secs_f64();
    let bytes = ALLOC.bytes() - bytes0;
    let calls = ALLOC.allocs() - calls0;
    let rounds_per_sec = rounds as f64 / secs;
    let tokens_per_sec = tokens as f64 / secs;
    let bytes_per_round = bytes as f64 / rounds.max(1) as f64;
    let allocs_per_round = calls as f64 / rounds.max(1) as f64;
    println!(
        "hotpath: {rounds_per_sec:.0} rounds/s  {tokens_per_sec:.0} tok/s  \
         {bytes_per_round:.0} B alloc/round  {allocs_per_round:.1} allocs/round \
         ({turns} turns)"
    );
    let mut j = Json::obj();
    j.push("bench", "end_to_end_hotpath")
        .push("backend", backend_name)
        .push("mode", engine.cfg.mode.as_str())
        .push("turns", turns)
        .push("rounds", rounds)
        .push("rounds_per_sec", rounds_per_sec)
        .push("tokens_per_sec", tokens_per_sec)
        .push("bytes_allocated_per_round", bytes_per_round)
        .push("allocs_per_round", allocs_per_round);
    std::fs::write("BENCH_hotpath.json", j.to_string_pretty()).unwrap();
    println!("wrote BENCH_hotpath.json");

    let mut cfg2 = cfg.clone();
    cfg2.tree.budget = 8;
    cfg2.tree.depth_max = 5;
    let mut b2 = backend();
    let mut engine2 = Engine::new(&mut *b2, cfg2);
    bench("turn_ea_m8_d5", 500.0, 3, || {
        engine2.reset();
        let out = engine2.generate_speculative(&prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });

    let mut cfg3 = cfg;
    cfg3.cache_strategy = CacheStrategy::DeepCopy;
    let mut b3 = backend();
    let mut engine3 = Engine::new(&mut *b3, cfg3);
    bench("turn_ea_m16_deepcopy", 500.0, 3, || {
        engine3.reset();
        let out = engine3.generate_speculative(&prompt, max_new).unwrap();
        black_box(out.tokens.len());
    });
}
