//! Cache-management ablation bench (paper §3.1 / ablation (i)):
//!
//!   * branch replication: DeepCopy (`Replicate = deepcopy`) vs
//!     SegmentShare;
//!   * commit: length-based vs path-index full reorder vs the
//!     prefix-sharing fast reorder (EA_FAST_CACHE_REORDER).
//!
//! Uses the real teacher cache geometry (L=4, C from the default
//! contract, H=4, Dh=32) so byte counts match production.

use eagle_pangu::cache::{pool_write, KvStore, ManagedCache, PagePool, PagedCache, BLOCK_ROWS};
use eagle_pangu::config::{CacheStrategy, Contract};
use eagle_pangu::util::bench::{bench, black_box};
use std::sync::RwLock;
use std::sync::Arc;

fn rows(dims: eagle_pangu::config::Dims, s: usize, base: f32) -> Vec<f32> {
    let rs = dims.heads * dims.d_head;
    (0..dims.layers * s * rs)
        .map(|i| base + (i % 97) as f32 * 0.01)
        .collect()
}

fn main() {
    let c = Contract::default();
    let dims = c.teacher;
    let cap = c.cache_cap;
    println!("== branch replication + commit (paper §3.1), teacher cache [{},{},{},{}] ==",
             dims.layers, cap, dims.heads, dims.d_head);

    let t0 = 256; // committed prefix
    let m = 17; // root + 16-node tree
    let k_new = rows(dims, 32, 100.0);
    let a = 5; // accepted path length incl. root

    for strategy in [CacheStrategy::DeepCopy, CacheStrategy::SegmentShare] {
        // full verification-round cache lifecycle: branch + append + commit
        let mut cache = ManagedCache::new(dims, cap, strategy, true);
        cache.append_committed(&rows(dims, 128, 1.0), &rows(dims, 128, 2.0), 128, 128).unwrap();
        cache.append_committed(&rows(dims, 128, 3.0), &rows(dims, 128, 4.0), 128, 128).unwrap();
        let path: Vec<usize> = (0..t0).chain((0..a).map(|i| t0 + i)).collect();
        bench(&format!("round_{}_path_commit_fast", strategy.as_str()), 30.0, 7, || {
            cache.begin_branch().unwrap();
            cache.append_branch(&k_new, &k_new, 32, m).unwrap();
            cache.commit_path(&path).unwrap();
            // rewind so the next iteration sees the same state
            unsafe_truncate(&mut cache, t0);
            black_box(cache.len());
        });

        let mut cache2 = ManagedCache::new(dims, cap, strategy, false);
        cache2.append_committed(&rows(dims, 128, 1.0), &rows(dims, 128, 2.0), 128, 128).unwrap();
        cache2.append_committed(&rows(dims, 128, 3.0), &rows(dims, 128, 4.0), 128, 128).unwrap();
        bench(&format!("round_{}_path_commit_full", strategy.as_str()), 30.0, 7, || {
            cache2.begin_branch().unwrap();
            cache2.append_branch(&k_new, &k_new, 32, m).unwrap();
            cache2.commit_path(&path).unwrap();
            unsafe_truncate(&mut cache2, t0);
            black_box(cache2.len());
        });

        let mut cache3 = ManagedCache::new(dims, cap, strategy, true);
        cache3.append_committed(&rows(dims, 128, 1.0), &rows(dims, 128, 2.0), 128, 128).unwrap();
        cache3.append_committed(&rows(dims, 128, 3.0), &rows(dims, 128, 4.0), 128, 128).unwrap();
        bench(&format!("round_{}_length_commit", strategy.as_str()), 30.0, 7, || {
            cache3.begin_branch().unwrap();
            cache3.append_branch(&k_new, &k_new, 32, m).unwrap();
            cache3.commit_length(a).unwrap();
            unsafe_truncate(&mut cache3, t0);
            black_box(cache3.len());
        });

        // Prefix-relative tail commit (the engine's steady-state fast
        // path): no identity-prefix vector, no gather scratch — compare
        // against round_*_path_commit_fast above.
        let mut cache4 = ManagedCache::new(dims, cap, strategy, true);
        cache4.append_committed(&rows(dims, 128, 1.0), &rows(dims, 128, 2.0), 128, 128).unwrap();
        cache4.append_committed(&rows(dims, 128, 3.0), &rows(dims, 128, 4.0), 128, 128).unwrap();
        // non-identity increasing tail: forces real row moves (an identity
        // tail would hit the `o == i` no-op fast-out under SegmentShare)
        let tail: Vec<usize> = (0..a).map(|i| i * 3 + (i > 0) as usize).collect();
        bench(&format!("round_{}_path_commit_tail", strategy.as_str()), 30.0, 7, || {
            cache4.begin_branch().unwrap();
            cache4.append_branch(&k_new, &k_new, 32, m).unwrap();
            cache4.commit_path_tail(&tail).unwrap();
            unsafe_truncate(&mut cache4, t0);
            black_box(cache4.len());
        });
    }

    // ---- paged layout: the block-table commit ----
    // Same round shape on a PagedCache (SegmentShare): the tail commit
    // moves only rows inside the partial boundary block and the table
    // trim, so compare against round_segment_path_commit_tail above —
    // and note the resident footprint next to the flat buffers.
    println!("== paged layout (block size {BLOCK_ROWS}) ==");
    let pool = Arc::new(RwLock::new(PagePool::new(dims, BLOCK_ROWS)));
    pool_write(&pool).ensure_headroom(cap);
    let mut paged = PagedCache::new(dims, cap, CacheStrategy::SegmentShare, true, pool.clone());
    paged.append_committed(&rows(dims, 128, 1.0), &rows(dims, 128, 2.0), 128, 128).unwrap();
    paged.append_committed(&rows(dims, 128, 3.0), &rows(dims, 128, 4.0), 128, 128).unwrap();
    let tail: Vec<usize> = (0..a).map(|i| i * 3 + (i > 0) as usize).collect();
    bench("round_paged_path_commit_tail", 30.0, 7, || {
        paged.begin_branch().unwrap();
        paged.append_branch(&k_new, &k_new, 32, m).unwrap();
        paged.commit_path_tail(&tail).unwrap();
        paged_truncate(&mut paged, t0);
        black_box(paged.len());
    });
    let mut paged2 = PagedCache::new(dims, cap, CacheStrategy::SegmentShare, true, pool.clone());
    paged2.append_committed(&rows(dims, 128, 1.0), &rows(dims, 128, 2.0), 128, 128).unwrap();
    paged2.append_committed(&rows(dims, 128, 3.0), &rows(dims, 128, 4.0), 128, 128).unwrap();
    bench("round_paged_length_commit", 30.0, 7, || {
        paged2.begin_branch().unwrap();
        paged2.append_branch(&k_new, &k_new, 32, m).unwrap();
        paged2.commit_length(a).unwrap();
        paged_truncate(&mut paged2, t0);
        black_box(paged2.len());
    });
    let flat_ref = ManagedCache::new(dims, cap, CacheStrategy::SegmentShare, true);
    println!(
        "resident bytes at t={t0}: paged {} vs flat {} (per conversation)",
        paged.bytes_resident(),
        KvStore::bytes_resident(&flat_ref)
    );
}

/// Paged rewind: identity-prefix path commit truncates to `to` rows.
fn paged_truncate(cache: &mut PagedCache, to: usize) {
    cache.begin_branch().unwrap();
    let path: Vec<usize> = (0..to).collect();
    cache.commit_path(&path).unwrap();
}

/// Test-only rewind: re-run rounds from the same committed length.
fn unsafe_truncate(cache: &mut ManagedCache, to: usize) {
    // commit_path with an identity prefix acts as a truncation
    cache.begin_branch().unwrap();
    let path: Vec<usize> = (0..to).collect();
    cache.commit_path(&path).unwrap();
}
