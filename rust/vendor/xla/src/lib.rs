//! API-compatible **stub** of the `xla` PJRT binding used by
//! [`PjrtBackend`](../../../src/runtime/pjrt.rs).
//!
//! The container this repo builds in does not ship `xla_extension` (the
//! C++ PJRT client + HLO parser), so this crate provides the exact type
//! and method surface the runtime layer compiles against, with every
//! entry point returning a descriptive [`XlaError`]. `PjrtBackend::load`
//! therefore fails cleanly at runtime and all callers (CLI, benches,
//! integration tests) fall back to the deterministic `SimBackend`.
//!
//! Swapping in the real binding is a one-line Cargo change: point the
//! `xla` dependency at the actual crate; no runtime-layer source edits
//! are required. The binding must additionally provide the two
//! donation/retention entry points this stub declares beyond the classic
//! surface — `Literal::read_into` (readback into preallocated host
//! scratch) and `PjRtBuffer::destructure_tuple` (split a tuple result
//! into retainable per-output device buffers) — both thin wrappers over
//! existing PJRT C-API calls.

use std::fmt;

/// Error type mirroring the binding's error enum closely enough for the
/// `{e:?}` / `.context(...)` call sites in `runtime/pjrt.rs`.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT runtime unavailable — this build vendors the stub `xla` crate \
         (rust/vendor/xla); install xla_extension and point Cargo at the real binding"
    ))
}

pub type Result<T> = std::result::Result<T, XlaError>;

/// PJRT client handle (stub). `cpu()` always fails.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }

    /// Upload a host tensor as an owned device buffer. Generic over the
    /// element type the way the real binding is (f32/i32 used here).
    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

/// Owned device buffer (stub). Drop frees in the real binding. Holding a
/// `PjRtBuffer` across calls is the buffer-*retention* entry point the
/// KV-session runtime relies on: a bound cache stays device-resident
/// between launches instead of being re-uploaded.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }

    /// Split a tuple-shaped result buffer into per-element device buffers
    /// **without** a host round-trip — the retention entry point that
    /// lets the KV-session scatter-update module's output buffers be fed
    /// straight back in as the next launch's cache inputs.
    pub fn destructure_tuple(self) -> Result<Vec<PjRtBuffer>> {
        Err(unavailable("PjRtBuffer::destructure_tuple"))
    }
}

/// Compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Borrowing execute — the only execute variant the runtime uses (the
    /// literal-taking `execute` leaks in the real C shim; see pjrt.rs).
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// Parsed HLO module proto (stub).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        Self { _priv: () }
    }
}

/// Host literal (stub).
pub struct Literal {
    _priv: (),
}

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    /// Output-donation readback: copy the literal's elements into a
    /// caller-preallocated host slice (exactly `dst.len()` elements —
    /// the real binding errors on a size mismatch). Removes the
    /// per-output `Vec` the `to_vec` path materializes, which is what
    /// keeps PJRT steps allocation-free under the scratch contract.
    pub fn read_into<T: Copy>(&self, dst: &mut [T]) -> Result<()> {
        let _ = dst;
        Err(unavailable("Literal::read_into"))
    }

    /// Element count of the literal (shape product).
    pub fn element_count(&self) -> Result<usize> {
        Err(unavailable("Literal::element_count"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_loudly_with_pointer_to_fix() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(e.to_string().contains("stub"), "{e}");
        assert!(format!("{e:?}").contains("vendor/xla"));
    }
}
