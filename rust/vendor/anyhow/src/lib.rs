//! Minimal in-repo reimplementation of the `anyhow` API surface this
//! repository uses: `Error`, `Result`, the `anyhow!`/`bail!`/`ensure!`
//! macros and the `Context` extension trait for `Result` and `Option`.
//!
//! The build image has no crates.io access (DESIGN.md: every substrate is
//! built in-repo), so this vendored crate stands in for the real one.
//! Semantics match where the repo depends on them:
//!
//! * `{}` displays the outermost context (most recent `.context(...)`);
//! * `{:#}` displays the whole chain, outermost first, `": "`-joined —
//!   the format the coordinator's failure dumps rely on;
//! * any `std::error::Error + Send + Sync + 'static` converts via `?`,
//!   with its `source()` chain flattened into the message chain.

use std::fmt;

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: an outermost-first chain of messages.
pub struct Error {
    /// `chain[0]` is the outermost (most recently attached) context;
    /// the last entry is the root cause.
    chain: Vec<String>,
}

impl Error {
    /// Build from a printable message (the `anyhow!` entry point).
    pub fn msg(msg: impl fmt::Display) -> Self {
        Self { chain: vec![msg.to_string()] }
    }

    /// Attach an outer context message.
    pub fn context(mut self, c: impl fmt::Display) -> Self {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The message chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints through Debug: show the
        // full chain the way the real anyhow does.
        write!(f, "{}", self.chain.join(": "))
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src: Option<&(dyn std::error::Error + 'static)> = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Self { chain }
    }
}

/// Context-attachment extension for `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file").context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chain_formats() {
        let e = io_fail().unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "{full}");
    }

    #[test]
    fn option_context_and_macros() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.root_cause(), "missing");
        let x = 3;
        let e2 = anyhow!("bad value {x}");
        assert_eq!(format!("{e2}"), "bad value 3");
        let e3 = anyhow!("bad value {}", 4);
        assert_eq!(format!("{e3}"), "bad value 4");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "precondition {} failed", "p");
            if !ok {
                bail!("unreachable");
            }
            Ok(7)
        }
        assert_eq!(f(true).unwrap(), 7);
        assert_eq!(format!("{}", f(false).unwrap_err()), "precondition p failed");
    }
}
