//! Fixture suite for the `static_check` analysis driver.
//!
//! Every rule gets the same three-way exercise against files under
//! `tests/fixtures/static_check/<rule>/`:
//!
//!   * **positive** — the violation fires, at the expected line;
//!   * **negative** — the clean shape (plus the classic false-positive
//!     bait: strings, comments, `#[cfg(test)]` code) stays silent;
//!   * **pragma** — a reasoned `lint: allow(...)` waiver flips the
//!     finding to `allowed` without deleting it from the report.
//!
//! Fixtures are real files (not inline strings) so they double as
//! documentation of what each rule means, and so the lexer runs over
//! content laid out exactly the way rustfmt would lay it out.
//!
//! The suite ends with the JSON-report schema test and a whole-repo
//! smoke run of [`analysis::run`] (shape and self-consistency only —
//! the zero-active gate lives in CI, where `static_check` itself runs).

use eagle_pangu::analysis::lexer::{scan_python, scan_rust, ScannedFile};
use eagle_pangu::analysis::{rules, Finding, Report, Severity, RULES};
use eagle_pangu::{analysis, json};
use std::path::Path;

/// Load a fixture by repo-relative name under the fixture root.
fn fixture(name: &str) -> String {
    let path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/static_check").join(name);
    std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("fixture {} unreadable: {e}", path.display()))
}

/// Mirror of the driver's pragma-application step ([`analysis::run`]):
/// a reasoned pragma on the finding's line (or the line above) waives
/// it; a reasonless pragma waives nothing.
fn apply_pragmas(scan: &ScannedFile, mut findings: Vec<Finding>) -> Vec<Finding> {
    for f in &mut findings {
        if let Some(p) = scan.pragma_for(f.rule, f.line) {
            if p.reason.is_some() {
                f.allowed = true;
                f.reason = p.reason.clone();
            }
        }
    }
    findings
}

/// Run one scanned-input rule over a fixture and apply pragmas.
fn drive(
    rule: fn(&ScannedFile) -> Vec<Finding>,
    path: &str,
    fixture_name: &str,
) -> Vec<Finding> {
    let scan = scan_rust(path, &fixture(fixture_name));
    let found = rule(&scan);
    apply_pragmas(&scan, found)
}

// ---------------------------------------------------------------- rules

#[test]
fn wall_clock_positive_negative_pragma() {
    let pos = drive(rules::wall_clock, "rust/src/coordinator/x.rs", "wall_clock/positive.rs");
    assert_eq!(pos.len(), 1, "{pos:?}");
    assert_eq!((pos[0].line, pos[0].rule), (5, "wall-clock"));
    assert!(!pos[0].allowed);

    let neg = drive(rules::wall_clock, "rust/src/coordinator/x.rs", "wall_clock/negative.rs");
    assert!(neg.is_empty(), "strings/comments/tests must not trip: {neg:?}");

    let prag = drive(rules::wall_clock, "rust/src/backend/x.rs", "wall_clock/pragma.rs");
    assert_eq!(prag.len(), 1, "waived findings stay in the report: {prag:?}");
    assert!(prag[0].allowed);
    assert!(prag[0].reason.as_deref().unwrap().contains("device clock"));
}

#[test]
fn signed_cast_positive_negative_pragma() {
    let pos = drive(rules::signed_cast, "rust/src/tree/x.rs", "signed_cast/positive.rs");
    assert_eq!(pos.len(), 1, "{pos:?}");
    assert_eq!(pos[0].line, 3);

    let neg = drive(rules::signed_cast, "rust/src/tree/x.rs", "signed_cast/negative.rs");
    assert!(neg.is_empty(), "udx/string/test casts must not trip: {neg:?}");

    let prag = drive(rules::signed_cast, "rust/src/cache/x.rs", "signed_cast/pragma.rs");
    assert_eq!(prag.len(), 1);
    assert!(prag[0].allowed, "same-line pragma must waive: {prag:?}");
}

#[test]
fn hot_unwrap_positive_negative_pragma() {
    let pos = drive(rules::hot_unwrap, "rust/src/engine/x.rs", "hot_unwrap/positive.rs");
    assert_eq!(pos.len(), 2, "{pos:?}");
    assert_eq!((pos[0].line, pos[1].line), (3, 4));

    let neg = drive(rules::hot_unwrap, "rust/src/engine/x.rs", "hot_unwrap/negative.rs");
    assert!(neg.is_empty(), "unwrap_or/let-else/strings/tests must not trip: {neg:?}");

    let prag = drive(rules::hot_unwrap, "rust/src/cache/x.rs", "hot_unwrap/pragma.rs");
    assert_eq!(prag.len(), 1);
    assert!(prag[0].allowed);
    assert!(prag[0].reason.as_deref().unwrap().contains("poisoning"));
}

#[test]
fn unsafe_code_positive_negative_pragma() {
    let pos = drive(rules::unsafe_code, "rust/src/x.rs", "unsafe_code/positive.rs");
    assert_eq!(pos.len(), 1, "{pos:?}");
    assert_eq!(pos[0].line, 3);

    let neg_scan = scan_rust("rust/src/lib.rs", &fixture("unsafe_code/negative.rs"));
    assert!(rules::unsafe_code(&neg_scan).is_empty(), "ident fragments must not trip");
    assert!(
        rules::forbid_attr_present(&neg_scan).is_empty(),
        "the forbid attr is present in the negative fixture"
    );
    // a lib.rs without the attr is itself a finding
    let bare = scan_rust("rust/src/lib.rs", "pub mod x;\n");
    assert_eq!(rules::forbid_attr_present(&bare).len(), 1);

    let prag = drive(rules::unsafe_code, "rust/src/x.rs", "unsafe_code/pragma.rs");
    assert_eq!(prag.len(), 1);
    assert!(prag[0].allowed, "preceding-line pragma must waive: {prag:?}");
}

#[test]
fn artifact_drift_positive_negative_pragma() {
    let pos = scan_python("python/compile/aot.py", &fixture("artifact_drift/positive.py"));
    let found = rules::artifact_drift(&pos);
    let lines: Vec<usize> = found.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![3, 4], "both drifted names fire: {found:?}");

    let neg = scan_python("python/compile/aot.py", &fixture("artifact_drift/negative.py"));
    let found = rules::artifact_drift(&neg);
    assert!(found.is_empty(), "schema names, role strings and docstrings are clean: {found:?}");

    let prag = scan_python("python/compile/aot.py", &fixture("artifact_drift/pragma.py"));
    let found = apply_pragmas(&prag, rules::artifact_drift(&prag));
    assert_eq!(found.len(), 1);
    assert!(found[0].allowed, "# pragma on the preceding line must waive: {found:?}");
}

#[test]
fn wire_tag_positive_negative_pragma() {
    let envelope = fixture("wire_tag/envelope.rs");
    let pinned = fixture("wire_tag/tests_pinned.rs");
    let missing = fixture("wire_tag/tests_missing.rs");

    let neg = rules::wire_tag("rust/src/rpc/envelope.rs", &envelope, &pinned);
    assert!(neg.is_empty(), "fully pinned tags are clean: {neg:?}");

    let pos = rules::wire_tag("rust/src/rpc/envelope.rs", &envelope, &missing);
    assert_eq!(pos.len(), 1, "{pos:?}");
    assert!(pos[0].message.contains("\"abort\""));
    assert!(pos[0].message.contains("not pinned"));

    let env_pragma = fixture("wire_tag/envelope_pragma.rs");
    let scan = scan_rust("rust/src/rpc/envelope.rs", &env_pragma);
    let found = apply_pragmas(
        &scan,
        rules::wire_tag("rust/src/rpc/envelope.rs", &env_pragma, &missing),
    );
    assert_eq!(found.len(), 1);
    assert!(found[0].allowed, "pragma above the arm must waive: {found:?}");

    // a file with no Envelope enum is one loud finding, not silence
    let none = rules::wire_tag("rust/src/rpc/envelope.rs", "pub struct NotAnEnum;", &pinned);
    assert_eq!(none.len(), 1);
}

#[test]
fn flag_doc_positive_negative_pragma() {
    let args = fixture("flag_doc/args.rs");
    let full = fixture("flag_doc/readme_full.md");
    let missing = fixture("flag_doc/readme_missing.md");

    let neg = rules::flag_doc("rust/src/cli/args.rs", &args, &full);
    assert!(neg.is_empty(), "documented flags are clean: {neg:?}");

    let pos = rules::flag_doc("rust/src/cli/args.rs", &args, &missing);
    assert_eq!(pos.len(), 1, "{pos:?}");
    assert!(pos[0].message.contains("--workers"));
    assert_eq!(pos[0].severity, Severity::Warn, "flag-doc is the one Warn rule");

    let args_pragma = fixture("flag_doc/args_pragma.rs");
    let scan = scan_rust("rust/src/cli/args.rs", &args_pragma);
    let found =
        apply_pragmas(&scan, rules::flag_doc("rust/src/cli/args.rs", &args_pragma, &missing));
    assert_eq!(found.len(), 1);
    assert!(found[0].allowed, "same-line pragma must waive: {found:?}");
}

#[test]
fn bad_pragma_positive_negative() {
    let pos = scan_rust("rust/src/x.rs", &fixture("bad_pragma/positive.rs"));
    let found = rules::audit_pragmas(&pos);
    assert_eq!(found.len(), 2, "{found:?}");
    assert!(found[0].message.contains("no reason"));
    assert!(found[1].message.contains("unknown rule"));

    let neg = scan_rust("rust/src/x.rs", &fixture("bad_pragma/negative.rs"));
    assert!(rules::audit_pragmas(&neg).is_empty(), "a reasoned pragma audits clean");
}

// ---------------------------------------------------------- JSON report

#[test]
fn json_report_schema() {
    // Build a report with one active and one waived finding.
    let scan = scan_rust("rust/src/engine/x.rs", &fixture("hot_unwrap/positive.rs"));
    let mut findings = rules::hot_unwrap(&scan);
    findings[0].allowed = true;
    findings[0].reason = Some("fixture waiver".to_string());
    let report = Report { findings, files_scanned: 1 };

    let text = report.to_json().to_string_pretty();
    let doc = json::parse(&text).expect("report must be valid JSON");

    assert_eq!(doc.get("tool").and_then(|t| t.as_str()), Some("static_check"));
    let rules_arr = doc.get("rules").and_then(|r| r.as_arr()).expect("rules array");
    assert_eq!(rules_arr.len(), RULES.len(), "every catalog rule is listed");
    for r in rules_arr {
        assert!(r.get("id").and_then(|v| v.as_str()).is_some());
        let sev = r.get("severity").and_then(|v| v.as_str()).expect("severity");
        assert!(sev == "error" || sev == "warn");
        assert!(r.get("summary").and_then(|v| v.as_str()).is_some());
    }

    let findings = doc.get("findings").and_then(|f| f.as_arr()).expect("findings array");
    assert_eq!(findings.len(), 2);
    for f in findings {
        assert!(f.get("file").and_then(|v| v.as_str()).is_some());
        assert!(f.get("line").and_then(|v| v.as_usize()).is_some());
        assert!(f.get("rule").and_then(|v| v.as_str()).is_some());
        assert!(f.get("allowed").and_then(|v| v.as_bool()).is_some());
        // reason: string when waived, null otherwise — always present
        assert!(f.get("reason").is_some());
    }
    let waived = findings.iter().filter(|f| f.get("allowed").unwrap().as_bool() == Some(true));
    assert_eq!(waived.count(), 1);

    let summary = doc.get("summary").expect("summary object");
    assert_eq!(summary.get("files_scanned").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(summary.get("total").and_then(|v| v.as_usize()), Some(2));
    assert_eq!(summary.get("allowed").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(summary.get("active").and_then(|v| v.as_usize()), Some(1));
    let per_rule = summary.get("per_rule").expect("per_rule object");
    let hu = per_rule.get("hot-unwrap").expect("per-rule bucket");
    assert_eq!(hu.get("active").and_then(|v| v.as_usize()), Some(1));
    assert_eq!(hu.get("allowed").and_then(|v| v.as_usize()), Some(1));
}

// ------------------------------------------------------- whole-repo run

#[test]
fn repo_run_is_self_consistent() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("repo root").to_path_buf();
    let report = analysis::run(&root).expect("driver must run over the real tree");
    assert!(report.files_scanned > 20, "the walker must find the source tree");
    assert_eq!(
        report.findings.len(),
        report.active() + report.allowed(),
        "every finding is exactly one of active/allowed"
    );
    for f in &report.findings {
        assert!(
            RULES.iter().any(|r| r.id == f.rule),
            "finding carries a cataloged rule id: {}",
            f.render()
        );
        assert!(
            f.allowed == f.reason.is_some(),
            "waived findings carry the pragma reason (and only those): {}",
            f.render()
        );
        assert!(f.line >= 1, "lines are 1-based: {}", f.render());
    }
    // waivers in the real tree are audited: every one carries a reason,
    // and none of them is a bad-pragma finding
    assert!(
        report.findings.iter().all(|f| f.rule != "bad-pragma"),
        "the real tree has no malformed pragmas"
    );
    // findings arrive file/line sorted (stable CI diffs)
    let keys: Vec<_> =
        report.findings.iter().map(|f| (f.file.clone(), f.line, f.rule)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted, "findings are file/line ordered");
}
