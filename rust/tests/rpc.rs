//! Property tests of the coordinator/worker RPC layer (satellite of the
//! multi-worker split):
//!
//! 1. **Round-trip totality** — every [`Envelope`] variant, filled with
//!    randomized payloads (including the option-heavy corners: SLO
//!    present/absent, abort-one vs abort-all, final vs streaming stats),
//!    survives serialize → deserialize through *both* codecs with its
//!    JSON form bit-identical.
//! 2. **Truncation honesty** — the framed codec names exactly what went
//!    wrong on cut-off or corrupted input instead of failing obscurely
//!    inside the JSON parser.
//! 3. **Channel semantics** — typed channels move real bytes, report
//!    `Disconnected` on peer drop, and `try_send` distinguishes a full
//!    queue from a dead one (the coordinator's deadlock-avoidance
//!    contract).

use eagle_pangu::cache::CacheStats;
use eagle_pangu::coordinator::{SchedulerStats, ShedNotice as SchedShedNotice, SloAction, SloPolicy};
use eagle_pangu::engine::GenOut;
use eagle_pangu::json;
use eagle_pangu::rpc::{
    wire_channel, Abort, ChannelError, Codec, Completion, Envelope, FramedJsonCodec, JsonCodec,
    Park, RequestKind, Resume, ShedNotice, Submit, TokenDelta, TurnDone, Wire, WorkerStats,
};
use eagle_pangu::util::stats::{AcceptPos, Histogram};
use eagle_pangu::util::{SplitMix64, StageTimer};

// -------------------------------------------------------------------
// Randomized payload builders. All numeric fields stay in ranges that
// are exact in f64 (the JSON value model is f64-backed): u64 < 2^32,
// f64 dyadic rationals.
// -------------------------------------------------------------------

fn rand_u64(rng: &mut SplitMix64) -> u64 {
    rng.next_u64() % 1_000_000
}

fn rand_f64(rng: &mut SplitMix64) -> f64 {
    (rng.next_u64() % 100_000) as f64 / 8.0
}

fn rand_tokens(rng: &mut SplitMix64, n: usize) -> Vec<i32> {
    (0..n).map(|_| (rng.next_u64() % 50_000) as i32 - 10_000).collect()
}

fn rand_shed(rng: &mut SplitMix64) -> SchedShedNotice {
    SchedShedNotice {
        id: rand_u64(rng),
        submitted_tick: rand_u64(rng),
        shed_tick: rand_u64(rng),
        waited_ms: rand_f64(rng),
        target_ms: rand_f64(rng),
    }
}

fn rand_stats(rng: &mut SplitMix64) -> SchedulerStats {
    SchedulerStats {
        submitted: rand_u64(rng),
        admitted: rand_u64(rng),
        retired: rand_u64(rng),
        parked: rand_u64(rng),
        resumed: rand_u64(rng),
        ticks: rand_u64(rng),
        fused_launches: rand_u64(rng),
        max_wait_ticks: rand_u64(rng),
        shed: rand_u64(rng),
        prefill_teacher_calls: rand_u64(rng),
    }
}

fn rand_cache_stats(rng: &mut SplitMix64) -> CacheStats {
    CacheStats {
        branches: rand_u64(rng),
        commits: rand_u64(rng),
        rollbacks: rand_u64(rng),
        replicate_bytes: rand_u64(rng),
        append_bytes: rand_u64(rng),
        commit_bytes: rand_u64(rng),
        fast_reorders: rand_u64(rng),
        fast_fallbacks: rand_u64(rng),
        full_reorders: rand_u64(rng),
        cow_copies: rand_u64(rng),
        cow_bytes: rand_u64(rng),
        adopted_rows: rand_u64(rng),
    }
}

fn rand_genout(rng: &mut SplitMix64) -> GenOut {
    let mut timers = StageTimer::new(false);
    timers.seconds.insert("draft".into(), rand_f64(rng));
    timers.seconds.insert("verify".into(), rand_f64(rng));
    timers.calls.insert("draft".into(), rand_u64(rng));
    timers.calls.insert("verify".into(), rand_u64(rng));
    let mut attn_hist = Histogram::new(vec![1.0, 2.0, 4.0, 8.0]);
    for _ in 0..8 {
        attn_hist.add((rng.next_u64() % 12) as f64);
    }
    let mut accept_pos = AcceptPos::default();
    for _ in 0..5 {
        let offered = 1 + (rng.next_u64() % 4) as usize;
        accept_pos.record((rng.next_u64() as usize) % (offered + 1), offered);
    }
    GenOut {
        tokens: rand_tokens(rng, 1 + (rng.next_u64() % 12) as usize),
        wall_secs: rand_f64(rng),
        teacher_calls: rand_u64(rng),
        draft_calls: rand_u64(rng),
        rounds: rand_u64(rng),
        accept_lens: (0..4).map(|_| (rng.next_u64() % 6) as usize).collect(),
        accept_pos,
        timers,
        attn_hist,
        teacher_cache: rand_cache_stats(rng),
        draft_cache: rand_cache_stats(rng),
        prompt_len: (rng.next_u64() % 64) as usize,
    }
}

fn rand_turn_done(rng: &mut SplitMix64) -> TurnDone {
    TurnDone {
        id: rand_u64(rng),
        rank: (rng.next_u64() % 8) as usize,
        turn: (rng.next_u64() % 4) as usize,
        out: rand_genout(rng),
        submitted_tick: rand_u64(rng),
        admitted_tick: rand_u64(rng),
        finished_tick: rand_u64(rng),
        waited_ticks: rand_u64(rng),
        finished_ms: rand_f64(rng),
    }
}

/// Every envelope variant, covering the optional/enum corners: SLO
/// present and absent, both request kinds, abort-one and abort-all,
/// streaming and final worker stats, error present and absent.
fn all_envelopes(rng: &mut SplitMix64) -> Vec<Envelope> {
    vec![
        Envelope::Submit(Submit {
            id: rand_u64(rng),
            prompt: rand_tokens(rng, 6),
            max_new: 1 + (rng.next_u64() % 16) as usize,
            arrival_ms: rand_f64(rng),
            kind: RequestKind::Ea,
            park_on_complete: true,
            slo: Some(SloPolicy { target_ms: rand_f64(rng), action: SloAction::Shed }),
            last: false,
            isolated: false,
        }),
        Envelope::Submit(Submit {
            id: rand_u64(rng),
            prompt: rand_tokens(rng, 1),
            max_new: 4,
            arrival_ms: rand_f64(rng),
            kind: RequestKind::Baseline,
            park_on_complete: false,
            slo: None,
            last: true,
            isolated: true,
        }),
        Envelope::Submit(Submit {
            id: rand_u64(rng),
            prompt: rand_tokens(rng, 3),
            max_new: 2,
            arrival_ms: rand_f64(rng),
            kind: RequestKind::Ea,
            park_on_complete: false,
            slo: Some(SloPolicy { target_ms: rand_f64(rng), action: SloAction::Queue }),
            last: true,
            isolated: false,
        }),
        Envelope::Resume(Resume {
            id: rand_u64(rng),
            prompt: rand_tokens(rng, 2),
            max_new: 1 + (rng.next_u64() % 8) as usize,
            park_on_complete: rng.next_u64() % 2 == 0,
        }),
        Envelope::Abort(Abort { id: Some(rand_u64(rng)) }),
        Envelope::Abort(Abort { id: None }),
        Envelope::TokenDelta(TokenDelta {
            id: rand_u64(rng),
            turn: (rng.next_u64() % 4) as usize,
            tokens: rand_tokens(rng, 1 + (rng.next_u64() % 5) as usize),
        }),
        Envelope::Park(Park { done: rand_turn_done(rng) }),
        Envelope::Completion(Completion { done: rand_turn_done(rng) }),
        Envelope::ShedNotice(ShedNotice { rank: (rng.next_u64() % 8) as usize, notice: rand_shed(rng) }),
        Envelope::WorkerStats(WorkerStats {
            rank: (rng.next_u64() % 8) as usize,
            stats: rand_stats(rng),
            shed: vec![rand_shed(rng), rand_shed(rng)],
            is_final: true,
            error: Some("engine exploded".into()),
        }),
        Envelope::WorkerStats(WorkerStats {
            rank: (rng.next_u64() % 8) as usize,
            stats: rand_stats(rng),
            shed: Vec::new(),
            is_final: false,
            error: None,
        }),
    ]
}

/// Serialize through `C`, deserialize, and require the rebuilt value's
/// JSON form to be bit-identical text (the lossless round-trip
/// contract of [`Wire`]).
fn assert_roundtrip<C: Codec>(env: &Envelope, codec_name: &str) {
    let mut bytes = Vec::new();
    C::serialize(&mut bytes, env).unwrap_or_else(|e| {
        panic!("{codec_name} failed to serialize {}: {e}", env.kind_str())
    });
    let back: Envelope = C::deserialize(bytes.as_slice()).unwrap_or_else(|e| {
        panic!("{codec_name} failed to deserialize {}: {e}", env.kind_str())
    });
    assert_eq!(back.kind_str(), env.kind_str(), "{codec_name} changed the variant tag");
    assert_eq!(
        back.to_json().to_string(),
        env.to_json().to_string(),
        "{codec_name} round trip of {} is not lossless",
        env.kind_str()
    );
}

#[test]
fn every_envelope_roundtrips_through_both_codecs() {
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(0xE11E ^ seed);
        for env in all_envelopes(&mut rng) {
            assert_roundtrip::<JsonCodec>(&env, "JsonCodec");
            assert_roundtrip::<FramedJsonCodec>(&env, "FramedJsonCodec");
        }
    }
}

#[test]
fn envelope_tags_are_stable_on_the_wire() {
    // The serialized form is a tagged union whose "type" field equals
    // kind_str() — the cross-process compatibility surface.
    let mut rng = SplitMix64::new(7);
    let expected = [
        "submit", "submit", "submit", "resume", "abort", "abort", "token_delta", "park",
        "completion", "shed_notice", "worker_stats", "worker_stats",
    ];
    let envs = all_envelopes(&mut rng);
    assert_eq!(envs.len(), expected.len());
    for (env, want) in envs.iter().zip(expected) {
        assert_eq!(env.kind_str(), want);
        let mut bytes = Vec::new();
        JsonCodec::serialize(&mut bytes, env).unwrap();
        let doc = json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap();
        assert_eq!(doc.get("type").and_then(|t| t.as_str()), Some(want));
        assert!(doc.get("body").is_some(), "{want} envelope must carry a body");
    }
}

#[test]
fn json_codec_rejects_garbage_and_unknown_tags() {
    let err = JsonCodec::deserialize::<_, Envelope>(&b"not json at all"[..]).unwrap_err();
    assert!(!err.to_string().is_empty());

    // Valid JSON, unknown tag: the error names the tag.
    let err = JsonCodec::deserialize::<_, Envelope>(&br#"{"type": "warp", "body": {}}"#[..])
        .unwrap_err();
    assert!(
        err.to_string().contains("unknown envelope type 'warp'"),
        "unexpected error: {err}"
    );

    // Valid JSON, right tag, hollow body: the error names the missing field.
    let err = JsonCodec::deserialize::<_, Envelope>(&br#"{"type": "abort", "body": {}}"#[..])
        .unwrap_err();
    assert!(err.to_string().contains("Abort"), "unexpected error: {err}");

    // A truncated JSON document fails the parse rather than yielding a value.
    let mut bytes = Vec::new();
    let env = Envelope::Abort(Abort { id: Some(3) });
    JsonCodec::serialize(&mut bytes, &env).unwrap();
    assert!(JsonCodec::deserialize::<_, Envelope>(&bytes[..bytes.len() - 2]).is_err());
}

#[test]
fn framed_codec_names_every_truncation() {
    let env = Envelope::TokenDelta(TokenDelta { id: 9, turn: 0, tokens: vec![1, 2, 3] });
    let mut bytes = Vec::new();
    FramedJsonCodec::serialize(&mut bytes, &env).unwrap();
    assert!(bytes.len() > 9, "framed form is header + body");

    // Whole-frame round trip works.
    let back: Envelope = FramedJsonCodec::deserialize(bytes.as_slice()).unwrap();
    assert_eq!(back.to_json().to_string(), env.to_json().to_string());

    let msg = |cut: &[u8]| {
        FramedJsonCodec::deserialize::<_, Envelope>(cut).unwrap_err().to_string()
    };
    // Cut inside the header (including the empty input).
    assert!(msg(&[]).contains("truncated frame header"));
    assert!(msg(&bytes[..5]).contains("truncated frame header"));
    // Header intact, body cut short (or absent): the error names the
    // byte count the frame promised.
    let want = format!("want {} bytes", bytes.len() - 9);
    assert!(msg(&bytes[..9]).contains("truncated frame body"));
    let cut_body = msg(&bytes[..bytes.len() - 3]);
    assert!(cut_body.contains(&want), "got: {cut_body}");
    // Corrupted headers are distinguished from truncated ones.
    assert!(msg(b"000000010").contains("malformed frame header"), "missing newline");
    assert!(msg(&[0xFF; 9]).contains("malformed frame header"), "non-UTF-8 digits");
    assert!(msg(b"zzzzzzzz\n").contains("malformed frame length"), "non-hex digits");
    // Frame intact but the body is not UTF-8.
    let mut bad = b"00000002\n".to_vec();
    bad.extend_from_slice(&[0xFF, 0xFE]);
    assert!(msg(&bad).contains("frame body not UTF-8"));
}

#[test]
fn wire_channel_moves_envelopes_and_reports_disconnects() {
    let (tx, rx) = wire_channel::<Envelope, JsonCodec>(8);
    let mut rng = SplitMix64::new(21);
    let sent = all_envelopes(&mut rng);
    for env in &sent {
        tx.send(env).unwrap();
    }
    for env in &sent {
        let got = rx.recv().unwrap();
        assert_eq!(got.to_json().to_string(), env.to_json().to_string());
    }
    // Empty but connected: try_recv yields None, not an error.
    assert_eq!(rx.try_recv().unwrap().map(|e| e.kind_str()), None);
    // Sender gone: the receiver learns, both blocking and polling.
    drop(tx);
    assert_eq!(rx.recv().unwrap_err(), ChannelError::Disconnected);
    assert_eq!(rx.try_recv().unwrap_err(), ChannelError::Disconnected);
}

#[test]
fn try_send_distinguishes_full_from_dead() {
    let (tx, rx) = wire_channel::<Envelope, FramedJsonCodec>(1);
    let env = Envelope::Abort(Abort { id: None });
    // Capacity 1: first enqueue fits, second reports Full as Ok(false).
    assert!(tx.try_send(&env).unwrap());
    assert!(!tx.try_send(&env).unwrap());
    // Draining one message frees the slot again.
    rx.recv().unwrap();
    assert!(tx.try_send(&env).unwrap());
    // A dead peer is an error, not backpressure.
    drop(rx);
    assert_eq!(tx.try_send(&env).unwrap_err(), ChannelError::Disconnected);
    assert_eq!(tx.send(&env).unwrap_err(), ChannelError::Disconnected);
}

#[test]
fn cloned_senders_feed_one_receiver() {
    let (tx, rx) = wire_channel::<Envelope, JsonCodec>(4);
    let tx2 = tx.clone();
    tx.send(&Envelope::Abort(Abort { id: Some(1) })).unwrap();
    tx2.send(&Envelope::Abort(Abort { id: Some(2) })).unwrap();
    let mut ids = Vec::new();
    for _ in 0..2 {
        match rx.recv().unwrap() {
            Envelope::Abort(a) => ids.push(a.id.unwrap()),
            other => panic!("unexpected envelope {}", other.kind_str()),
        }
    }
    assert_eq!(ids, vec![1, 2]);
    // The channel dies only when *every* sender clone is gone.
    drop(tx);
    assert_eq!(rx.try_recv().unwrap().map(|e| e.kind_str()), None);
    drop(tx2);
    assert_eq!(rx.recv().unwrap_err(), ChannelError::Disconnected);
}
