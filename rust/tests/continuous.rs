//! Continuous-batching acceptance properties (the tentpole claims of the
//! slot-lifecycle batching contract, `docs/ARCHITECTURE.md`):
//!
//! 1. **Arrival-schedule bit-identity** — for random requests (mixed
//!    configs, prompts, deadlines) arriving at random ticks into a
//!    running group of B ∈ 1..=8 slots, every conversation's output is
//!    exactly its sequential `generate_speculative` decode, no matter
//!    when it was admitted or who its slot-mates were.
//! 2. **Fairness / no starvation** — admission is FIFO (a conversation
//!    never overtakes an earlier-submitted one) and every ready
//!    conversation waits at most a workload-derived bounded number of
//!    ticks for a slot.
//! 3. **Multi-turn residency** — a retiring turn that *continues* on its
//!    slot (engine context preserved) decodes its follow-up turn exactly
//!    like a dedicated sequential engine.

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::config::{CacheLayout, CacheStrategy, CommitMode, RunConfig};
use eagle_pangu::coordinator::{
    Completion, ContinuousScheduler, Disposition, SloAction, SloPolicy, SlotRequest,
};
use eagle_pangu::engine::{Engine, GenOut};
use eagle_pangu::util::prop;
use eagle_pangu::util::SplitMix64;

/// Base config of the CI feature matrix: `EA_CACHE_LAYOUT` (flat | paged)
/// selects the KV layout per matrix cell, `EA_PIPELINE` (on | off) selects
/// whether the serve loop software-pipelines launches; unset (local runs)
/// = flat + pipelined. Every scheduling property below must hold
/// identically in every cell.
fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    if let Ok(v) = std::env::var("EA_CACHE_LAYOUT") {
        cfg.cache_layout = CacheLayout::parse(&v).expect("EA_CACHE_LAYOUT must be flat|paged");
    }
    if let Ok(v) = std::env::var("EA_PIPELINE") {
        cfg.pipelining = match v.as_str() {
            "on" => true,
            "off" => false,
            _ => panic!("EA_PIPELINE must be on|off"),
        };
    }
    cfg
}

fn prompt(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = SplitMix64::new(seed);
    let mut p = vec![1i32]; // BOS
    for _ in 1..n.max(2) {
        p.push(rng.range(2, 512) as i32);
    }
    p
}

/// One randomized request spec (mirrors `tests/batched.rs`).
struct Req {
    cfg: RunConfig,
    prompt: Vec<i32>,
    max_new: usize,
    arrival: u64,
}

fn random_request(g: &mut prop::Gen, max_arrival: u64) -> Req {
    let mut cfg = base_cfg();
    cfg.tree.budget = g.usize_in(1, 33); // ragged padded variants
    cfg.tree.depth_max = g.usize_in(2, 11);
    cfg.tree.topk = g.usize_in(1, 5);
    if g.bool_p(0.2) {
        cfg.draft_window = Some(g.usize_in(4, 48));
    }
    if g.bool_p(0.2) {
        cfg.adaptive_budget = true;
    }
    if g.bool_p(0.15) {
        cfg.cache_strategy = CacheStrategy::DeepCopy;
    }
    if g.bool_p(0.25) {
        cfg.commit_mode = CommitMode::Length;
    }
    if g.bool_p(0.15) {
        cfg.fast_reorder = false;
    }
    let p_len = g.usize_in(4, 48);
    // one-token stragglers next to long turns: the ragged-traffic case
    // continuous admission exists for
    let max_new = if g.bool_p(0.3) { g.usize_in(1, 3) } else { g.usize_in(4, 25) };
    let arrival = g.usize_in(0, max_arrival as usize + 1) as u64;
    Req { cfg, prompt: prompt(p_len, g.rng.next_u64()), max_new, arrival }
}

/// Drive a scheduler over an arrival schedule until every request
/// completes; returns (outputs by request index, completions in
/// retirement order).
fn drive_schedule(
    agree: u64,
    slots: usize,
    reqs: &[Req],
) -> (Vec<GenOut>, Vec<(u64, u64, u64)>) {
    let mut bk = SimBackend::new(agree);
    let mut engines: Vec<Engine> =
        (0..slots).map(|_| Engine::new(&bk, base_cfg())).collect();
    let cap = bk.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(slots, cap);
    sched.set_pipelining(base_cfg().pipelining);

    let n = reqs.len();
    // submission order: by arrival tick, ties by request index
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&i| reqs[i].arrival);
    let mut next = 0usize;
    let mut outs: Vec<Option<GenOut>> = (0..n).map(|_| None).collect();
    // (id, admitted_tick, waited_ticks) in retirement order
    let mut timeline: Vec<(u64, u64, u64)> = Vec::new();
    let mut done = 0usize;
    let mut safety = 0u32;
    while done < n {
        while next < n && reqs[order[next]].arrival <= sched.current_tick() {
            let i = order[next];
            sched.submit(SlotRequest {
                id: i as u64,
                prompt: reqs[i].prompt.clone(),
                max_new: reqs[i].max_new,
                cfg: Some(reqs[i].cfg.clone()),
                slo: None,
            });
            next += 1;
        }
        sched
            .tick(&mut bk, &mut engines, &mut |c: Completion| {
                timeline.push((c.id, c.admitted_tick, c.waited_ticks));
                outs[c.id as usize] = Some(c.out);
                done += 1;
                Disposition::Release
            })
            .unwrap();
        safety += 1;
        assert!(safety < 100_000, "scheduler failed to converge");
    }
    assert!(sched.is_idle());
    assert_eq!(sched.stats.admitted, n as u64);
    assert_eq!(sched.stats.retired, n as u64);
    (outs.into_iter().map(|o| o.expect("request completed")).collect(), timeline)
}

#[test]
fn property_arrival_schedules_are_bit_identical_to_sequential() {
    prop::for_cases(10, 0xC0_7141, |g| {
        let slots = g.usize_in(1, 9); // B in 1..=8
        let n = g.usize_in(1, 13);
        let agree = *g.choose(&[0u64, 60, 85, 100]);
        let reqs: Vec<Req> = (0..n).map(|_| random_request(g, 12)).collect();

        // sequential reference: one fresh backend + engine per request
        let seq: Vec<GenOut> = reqs
            .iter()
            .map(|r| {
                let mut b = SimBackend::new(agree);
                let mut e = Engine::new(&b, r.cfg.clone());
                e.generate_speculative(&mut b, &r.prompt, r.max_new).unwrap()
            })
            .collect();

        let (outs, _) = drive_schedule(agree, slots, &reqs);
        for (i, (got, want)) in outs.iter().zip(&seq).enumerate() {
            assert_eq!(
                got.tokens, want.tokens,
                "request {i} tokens diverged (slots={slots}, n={n}, agree={agree}, \
                 arrival={})",
                reqs[i].arrival
            );
            assert_eq!(got.accept_lens, want.accept_lens, "request {i} acceptance diverged");
            assert_eq!(got.rounds, want.rounds, "request {i} round count diverged");
            assert_eq!(got.teacher_calls, want.teacher_calls, "request {i} call accounting");
        }
    });
}

#[test]
fn property_admission_is_fifo_with_bounded_wait() {
    prop::for_cases(10, 0xFA_1257, |g| {
        let slots = g.usize_in(1, 5);
        let n = g.usize_in(3, 21);
        let max_new_max = 6usize;
        let reqs: Vec<Req> = (0..n)
            .map(|_| {
                let mut r = random_request(g, 15);
                r.max_new = g.usize_in(1, max_new_max + 1);
                r.cfg = base_cfg(); // uniform config: isolate scheduling
                r
            })
            .collect();

        let (_, timeline) = drive_schedule(90, slots, &reqs);
        assert_eq!(timeline.len(), n);

        // submission order (arrival tick, ties by index) — the FIFO line
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by_key(|&i| reqs[i].arrival);
        let mut admitted_of = vec![0u64; n];
        let mut waited_of = vec![0u64; n];
        for &(id, admitted, waited) in &timeline {
            admitted_of[id as usize] = admitted;
            waited_of[id as usize] = waited;
        }
        // 1. no overtaking: a later submission is never admitted before
        //    an earlier one
        for w in order.windows(2) {
            assert!(
                admitted_of[w[0]] <= admitted_of[w[1]],
                "request {} (arrival {}) overtook request {} (arrival {})",
                w[1], reqs[w[1]].arrival, w[0], reqs[w[0]].arrival
            );
        }
        // 2. bounded wait: a synchronous slot turns over within max_new + 1
        //    ticks (every tick commits >= 1 token; retirement takes one
        //    more). Under pipelining a slot-round can span two ticks — the
        //    wave that stages it overlaps the other half of the group's
        //    flight — so the per-round factor doubles, but the bound stays
        //    workload-derived: FIFO admission bounds any wait by the queue
        //    ahead of it.
        let bound = ((n as u64) / (slots as u64) + 2) * 2 * (max_new_max as u64 + 2);
        for i in 0..n {
            assert!(
                waited_of[i] <= bound,
                "request {i} waited {} ticks (> bound {bound}) — starvation",
                waited_of[i]
            );
        }
    });
}

#[test]
fn mixed_exec_modes_coexist_in_one_running_group() {
    // per-request configs may disagree on ExecMode; the scheduler must
    // split launches at mode boundaries instead of erroring the drive,
    // and every output stays bit-identical to sequential.
    use eagle_pangu::config::ExecMode;
    let agree = 85u64;
    let reqs: Vec<Req> = (0..4)
        .map(|i| {
            let mut cfg = base_cfg();
            cfg.mode = if i % 2 == 0 { ExecMode::Fused } else { ExecMode::Eager };
            Req { cfg, prompt: prompt(10 + i, 4000 + i as u64), max_new: 10, arrival: 0 }
        })
        .collect();
    let seq: Vec<GenOut> = reqs
        .iter()
        .map(|r| {
            let mut b = SimBackend::new(agree);
            let mut e = Engine::new(&b, r.cfg.clone());
            e.generate_speculative(&mut b, &r.prompt, r.max_new).unwrap()
        })
        .collect();
    let (outs, _) = drive_schedule(agree, 4, &reqs);
    for (got, want) in outs.iter().zip(&seq) {
        assert_eq!(got.tokens, want.tokens);
        assert_eq!(got.accept_lens, want.accept_lens);
    }
}

#[test]
fn multi_turn_continuation_on_slots_matches_sequential() {
    // Three 2-turn conversations over two slots: turn 2 begins via
    // Disposition::Continue on the retiring slot (context preserved),
    // while the third conversation is admitted into whichever slot frees
    // first — outputs must equal dedicated sequential engines.
    let agree = 85u64;
    let p1: Vec<Vec<i32>> = (0..3).map(|i| prompt(10 + i * 5, 2100 + i as u64)).collect();
    let p2: Vec<Vec<i32>> = (0..3).map(|i| prompt(6, 2200 + i as u64)).collect();

    let seq: Vec<(Vec<i32>, Vec<i32>)> = (0..3)
        .map(|i| {
            let mut b = SimBackend::new(agree);
            let mut e = Engine::new(&b, base_cfg());
            let o1 = e.generate_speculative(&mut b, &p1[i], 14).unwrap();
            let o2 = e.generate_speculative(&mut b, &p2[i], 14).unwrap();
            (o1.tokens, o2.tokens)
        })
        .collect();

    let mut bk = SimBackend::new(agree);
    let mut engines: Vec<Engine> =
        (0..2).map(|_| Engine::new(&bk, base_cfg())).collect();
    let cap = bk.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(2, cap);
    for (i, p) in p1.iter().enumerate() {
        sched.submit(SlotRequest {
            id: i as u64,
            prompt: p.clone(),
            max_new: 14,
            cfg: None,
            slo: None,
        });
    }
    let mut turn_of = [0usize; 3];
    let mut got: Vec<(Vec<i32>, Vec<i32>)> = vec![(Vec::new(), Vec::new()); 3];
    sched
        .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
            let i = c.id as usize;
            if turn_of[i] == 0 {
                got[i].0 = c.out.tokens;
                turn_of[i] = 1;
                Disposition::Continue { prompt: p2[i].clone(), max_new: 14 }
            } else {
                got[i].1 = c.out.tokens;
                Disposition::Release
            }
        })
        .unwrap();

    for i in 0..3 {
        assert_eq!(got[i].0, seq[i].0, "turn 1 diverged for conversation {i}");
        assert_eq!(got[i].1, seq[i].1, "turn 2 diverged for conversation {i}");
    }
    // 3 admissions, 6 retirements (one per turn), continuations reuse slots
    assert_eq!(sched.stats.admitted, 3);
    assert_eq!(sched.stats.retired, 6);
}

#[test]
fn continuous_admission_amortizes_launches_on_straggler_traffic() {
    // The throughput claim behind the tentpole: under ragged deadlines
    // (7 one-round stragglers + 1 long turn per 8 conversations), a
    // continuously refilled group issues FEWER teacher launches than
    // fixed chunked grouping, because freed slots are reused mid-flight
    // instead of draining the group.
    let agree = 90u64;
    let n = 16usize;
    let slots = 8usize;
    let prompts: Vec<Vec<i32>> = (0..n).map(|i| prompt(16, 3000 + i as u64)).collect();
    let deadline = |i: usize| if i % 8 == 7 { 24 } else { 1 };

    let run = |continuous: bool| -> (u64, Vec<GenOut>) {
        let mut bk = SimBackend::new(agree);
        let mut engines: Vec<Engine> =
            (0..slots).map(|_| Engine::new(&bk, base_cfg())).collect();
        let cap = bk.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(slots, cap);
        let mut outs: Vec<Option<GenOut>> = (0..n).map(|_| None).collect();
        let chunk_size = if continuous { n } else { slots };
        for chunk in (0..n).collect::<Vec<_>>().chunks(chunk_size) {
            for &i in chunk {
                sched.submit(SlotRequest {
                    id: i as u64,
                    prompt: prompts[i].clone(),
                    max_new: deadline(i),
                    cfg: None,
                    slo: None,
                });
            }
            sched
                .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
                    outs[c.id as usize] = Some(c.out);
                    Disposition::Release
                })
                .unwrap();
        }
        (bk.teacher_calls, outs.into_iter().map(Option::unwrap).collect())
    };

    let (fixed_launches, fixed_outs) = run(false);
    let (cont_launches, cont_outs) = run(true);
    assert!(
        cont_launches < fixed_launches,
        "continuous admission must amortize launches: {cont_launches} vs {fixed_launches}"
    );
    // and of course: identical tokens either way
    for (a, b) in fixed_outs.iter().zip(&cont_outs) {
        assert_eq!(a.tokens, b.tokens);
    }
}

#[test]
fn property_pipelined_serving_is_bit_identical_to_synchronous() {
    // The tentpole A/B invariant behind `--pipelining`: the software-
    // pipelined serve loop (double-buffered half-ticks, each wave's
    // launch resolved one wave late) must produce exactly the tokens of
    // the synchronous reference (stage -> launch -> resolve inline)
    // under random arrivals, mixed budgets and exec modes, and
    // mid-flight membership churn — release, park + resume, and
    // continue all happening while another wave is in flight.
    use eagle_pangu::config::ExecMode;
    prop::for_cases(8, 0x0DD_B175, |g| {
        let slots = g.usize_in(1, 9); // B in 1..=8
        let n = g.usize_in(2, 11);
        let agree = *g.choose(&[0u64, 60, 85, 100]);
        let mut reqs: Vec<Req> = (0..n).map(|_| random_request(g, 10)).collect();
        for r in reqs.iter_mut() {
            if g.bool_p(0.3) {
                r.cfg.mode = ExecMode::Eager;
            }
        }
        // per-conversation second-act plan: 0 = release on completion,
        // 1 = park, then resume 3 ticks later, 2 = continue on the slot
        let churn: Vec<u8> = (0..n).map(|_| *g.choose(&[0u8, 0, 1, 2])).collect();

        let run = |pipelining: bool| -> Vec<(GenOut, Option<GenOut>)> {
            let mut bk = SimBackend::new(agree);
            let mut engines: Vec<Engine> =
                (0..slots).map(|_| Engine::new(&bk, base_cfg())).collect();
            let cap = bk.contract().cache_cap;
            let mut sched = ContinuousScheduler::new(slots, cap);
            sched.set_pipelining(pipelining);
            let mut order: Vec<usize> = (0..n).collect();
            order.sort_by_key(|&i| reqs[i].arrival);
            let mut next = 0usize;
            let mut outs: Vec<(Option<GenOut>, Option<GenOut>)> =
                (0..n).map(|_| (None, None)).collect();
            let total = n + churn.iter().filter(|&&c| c != 0).count();
            let mut done = 0usize;
            let mut resume_at: Vec<(u64, u64)> = Vec::new();
            let mut safety = 0u32;
            while done < total {
                while next < n && reqs[order[next]].arrival <= sched.current_tick() {
                    let i = order[next];
                    sched.submit(SlotRequest {
                        id: i as u64,
                        prompt: reqs[i].prompt.clone(),
                        max_new: reqs[i].max_new,
                        cfg: Some(reqs[i].cfg.clone()),
                        slo: None,
                    });
                    next += 1;
                }
                let now = sched.current_tick();
                let due: Vec<u64> = resume_at
                    .iter()
                    .filter(|&&(_, at)| at <= now)
                    .map(|&(id, _)| id)
                    .collect();
                resume_at.retain(|&(_, at)| at > now);
                for id in due {
                    sched.resume(id, prompt(6, 9100 + id), 6).unwrap();
                }
                sched
                    .tick(&mut bk, &mut engines, &mut |c: Completion| {
                        let i = c.id as usize;
                        done += 1;
                        if outs[i].0.is_none() {
                            outs[i].0 = Some(c.out);
                            match churn[i] {
                                1 => {
                                    resume_at.push((c.id, c.finished_tick + 3));
                                    Disposition::Park
                                }
                                2 => Disposition::Continue {
                                    prompt: prompt(6, 9100 + c.id),
                                    max_new: 6,
                                },
                                _ => Disposition::Release,
                            }
                        } else {
                            outs[i].1 = Some(c.out);
                            Disposition::Release
                        }
                    })
                    .unwrap();
                safety += 1;
                assert!(safety < 100_000, "churn drive failed to converge");
            }
            assert!(sched.is_idle());
            outs.into_iter().map(|(a, b)| (a.expect("turn 1 completed"), b)).collect()
        };

        let sync = run(false);
        let pipe = run(true);
        for (i, (s, p)) in sync.iter().zip(&pipe).enumerate() {
            assert_eq!(
                s.0.tokens, p.0.tokens,
                "conversation {i} turn 1 tokens diverged under pipelining \
                 (slots={slots}, n={n}, agree={agree}, churn={})",
                churn[i]
            );
            assert_eq!(s.0.accept_lens, p.0.accept_lens, "conversation {i} acceptance diverged");
            assert_eq!(s.0.teacher_calls, p.0.teacher_calls, "conversation {i} call accounting");
            match (&s.1, &p.1) {
                (Some(a), Some(b)) => {
                    assert_eq!(a.tokens, b.tokens, "conversation {i} turn 2 tokens diverged");
                    assert_eq!(a.accept_lens, b.accept_lens, "conversation {i} turn 2 acceptance");
                }
                (None, None) => {}
                _ => panic!("conversation {i}: turn 2 completed in one mode but not the other"),
            }
        }
    });
}

#[test]
fn pipelined_split_launches_preserve_tokens_and_width_cap() {
    // Capability-capped width under the pipelined loop: a staged wave
    // wider than the widest compiled variant answers SplitRequired, and
    // the sub-launches pipeline within the pass (each resolves the
    // previous in-flight launch before beginning its own). Tokens must
    // equal sequential, and no launch may exceed the cap. 6 slots with
    // the fusion cap at 2 makes the cold priming wave 3 wide — wider
    // than the cap, forcing the pipelined split path.
    let agree = 88u64;
    let n = 6usize;
    let prompts: Vec<Vec<i32>> = (0..n).map(|i| prompt(9 + i, 8200 + i as u64)).collect();
    let seq: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            let mut b = SimBackend::new(agree);
            let mut e = Engine::new(&b, base_cfg());
            e.generate_speculative(&mut b, p, 16).unwrap().tokens
        })
        .collect();

    let mut bk = SimBackend::new(agree).with_max_fused(2);
    let mut engines: Vec<Engine> = (0..n).map(|_| Engine::new(&bk, base_cfg())).collect();
    let cap = bk.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(n, cap);
    sched.set_pipelining(true);
    let mut outs: Vec<Option<Vec<i32>>> = (0..n).map(|_| None).collect();
    for (i, p) in prompts.iter().enumerate() {
        sched.submit(SlotRequest {
            id: i as u64,
            prompt: p.clone(),
            max_new: 16,
            cfg: None,
            slo: None,
        });
    }
    sched
        .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
            outs[c.id as usize] = Some(c.out.tokens);
            Disposition::Release
        })
        .unwrap();
    for (i, o) in outs.iter().enumerate() {
        assert_eq!(
            o.as_deref().expect("completed"),
            &seq[i][..],
            "conversation {i} diverged under pipelined split launches"
        );
    }
    assert!(
        bk.launches_by_width.get(2).copied().unwrap_or(0) > 0,
        "capped pipelined waves must still fuse at the cap width: {:?}",
        bk.launches_by_width
    );
    assert_eq!(
        bk.launches_by_width.iter().skip(3).sum::<u64>(),
        0,
        "no pipelined launch may exceed the capability cap: {:?}",
        bk.launches_by_width
    );
}

#[test]
fn pipelined_serving_overlaps_host_work_with_inflight_launches() {
    // The perf claim, made deterministic: with a nonzero modeled teacher
    // launch cost and nonzero host-side draft cost, the pipelined drive
    // must hide *some* host work behind in-flight launches (the sim
    // banks the device seconds the host did not have to wait into
    // `overlap_saved_secs`) — and hiding it must not change a single
    // token.
    use std::time::Duration;
    let agree = 90u64;
    let slots = 8usize;
    let run = |pipelining: bool| -> (f64, Vec<Vec<i32>>) {
        let mut bk = SimBackend::new(agree)
            .with_teacher_launch(Duration::from_micros(400))
            .with_draft_cost(Duration::from_micros(200));
        let mut engines: Vec<Engine> =
            (0..slots).map(|_| Engine::new(&bk, base_cfg())).collect();
        let cap = bk.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(slots, cap);
        sched.set_pipelining(pipelining);
        let mut outs: Vec<Option<Vec<i32>>> = (0..slots).map(|_| None).collect();
        for i in 0..slots {
            sched.submit(SlotRequest {
                id: i as u64,
                prompt: prompt(12, 7000 + i as u64),
                max_new: 8,
                cfg: None,
                slo: None,
            });
        }
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
                outs[c.id as usize] = Some(c.out.tokens);
                Disposition::Release
            })
            .unwrap();
        (bk.overlap_saved_secs, outs.into_iter().map(Option::unwrap).collect())
    };
    let (saved_sync, toks_sync) = run(false);
    let (saved_pipe, toks_pipe) = run(true);
    assert!(
        saved_pipe > 0.0,
        "pipelined drive hid no host work behind in-flight launches"
    );
    // the synchronous path awaits each launch immediately, so it can
    // only ever bank the sim's own output-compute window — the pipelined
    // drive additionally hides the *other wave's* draft expansion
    // (200us of host spin per draft dispatch), a strictly larger save
    assert!(
        saved_pipe > saved_sync,
        "pipelining saved {saved_pipe}s, not more than the synchronous floor {saved_sync}s"
    );
    assert_eq!(toks_sync, toks_pipe, "overlap changed decoded tokens");
}

#[test]
fn matrix_cell_serving_is_token_identical_to_sequential() {
    // The CI feature-matrix cell test: run the full workload runner under
    // this cell's (EA_SCHEDULING, EA_CACHE_LAYOUT) combination at
    // max_batch = 4 and require record-for-record token identity against
    // the sequential (max_batch = 1) reference under the same layout.
    use eagle_pangu::coordinator::{
        run_workload, AdmissionPolicy, BackendSpec, CoordinatorConfig,
    };
    use eagle_pangu::workload::WorkloadSpec;
    use std::path::PathBuf;

    let scheduling = std::env::var("EA_SCHEDULING")
        .map(|v| AdmissionPolicy::parse(&v).expect("EA_SCHEDULING must be continuous|chunked"))
        .unwrap_or(AdmissionPolicy::Continuous);
    let tmp = |tag: &str| -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("eagle_matrix_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    };
    let mut run = base_cfg();
    run.max_new_tokens = 12;
    let cfg = |tag: &str, batch: usize, policy: AdmissionPolicy| CoordinatorConfig {
        world_size: 2,
        run: run.clone(),
        workload: WorkloadSpec::smoke(),
        backend: BackendSpec::Sim { agree_pct: 90 },
        trace_dir: tmp(tag),
        run_baseline: false,
        run_ea: true,
        max_batch: batch,
        scheduling: policy,
        verbose: false,
    };
    let seq_cfg = cfg("seq", 1, AdmissionPolicy::Continuous);
    let seq = run_workload(&seq_cfg).unwrap();
    let cell_cfg = cfg("cell", 4, scheduling);
    let cell = run_workload(&cell_cfg).unwrap();
    assert_eq!(seq.len(), cell.len(), "record count diverged in this matrix cell");
    for (a, b) in seq.iter().zip(&cell) {
        assert_eq!(a.conversation_id, b.conversation_id);
        assert_eq!(a.turn_idx, b.turn_idx);
        assert_eq!(
            a.output_len, b.output_len,
            "cell ({}, {}) diverged at conv {} turn {}",
            cell_cfg.scheduling.as_str(),
            run.cache_layout.as_str(),
            a.conversation_id,
            a.turn_idx
        );
        assert_eq!(a.accept_lens, b.accept_lens);
        assert_eq!(a.teacher_calls, b.teacher_calls);
    }
    let _ = std::fs::remove_dir_all(&seq_cfg.trace_dir);
    let _ = std::fs::remove_dir_all(&cell_cfg.trace_dir);
}

// ----------------------------------------------------------------------
// SLO admission under overload (`--slo-ms` / `--slo-action`)
// ----------------------------------------------------------------------

#[test]
fn shed_action_drops_exactly_the_over_deadline_requests() {
    // One slot, sustained overload (everything queued at once), a 25 ms
    // shed deadline, 10 virtual ms per tick. The contract has two sides:
    // every shed notice shows a wait strictly over the deadline, and
    // every completed request was admitted while still inside it (the
    // sweep runs before admission each tick, so nothing expired can slip
    // into a slot).
    let target_ms = 25.0;
    let slo = SloPolicy { target_ms, action: SloAction::Shed };
    let mut bk = SimBackend::new(90);
    let mut engines = vec![Engine::new(&bk, base_cfg())];
    let cap = bk.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(1, cap);
    sched.set_pipelining(base_cfg().pipelining);
    let n = 10u64;
    for i in 0..n {
        sched.submit(SlotRequest {
            id: i,
            prompt: prompt(8, 500 + i),
            max_new: 2,
            cfg: None,
            slo: Some(slo),
        });
    }
    // pre-tick virtual clock by tick number (all requests arrived at 0 ms,
    // so the clock at a tick IS the queue wait any request admitted or
    // swept on that tick had accumulated)
    let mut clock_before: Vec<(u64, f64)> = Vec::new();
    let mut completions: Vec<(u64, u64)> = Vec::new(); // (id, admitted_tick)
    let mut notices = Vec::new();
    let mut safety = 0u32;
    while !sched.is_idle() {
        clock_before.push((sched.current_tick(), sched.now_ms()));
        sched
            .tick(&mut bk, &mut engines, &mut |c: Completion| {
                assert_eq!(c.slo, Some(slo), "completions must echo the submitted SLO");
                completions.push((c.id, c.admitted_tick));
                Disposition::Release
            })
            .unwrap();
        sched.advance_clock(10.0);
        notices.extend(sched.drain_shed());
        safety += 1;
        assert!(safety < 10_000, "overload drive failed to converge");
    }
    assert!(!notices.is_empty(), "sustained overload past the deadline must shed");
    assert!(!completions.is_empty(), "requests inside the deadline must complete");
    assert_eq!(
        completions.len() + notices.len(),
        n as usize,
        "every request completes or sheds, never vanishes"
    );
    assert_eq!(sched.stats.shed, notices.len() as u64);
    let wait_at = |tick: u64| -> f64 {
        clock_before
            .iter()
            .find(|&&(t, _)| t == tick)
            .map(|&(_, ms)| ms)
            .expect("tick was driven")
    };
    for nt in &notices {
        assert_eq!(nt.target_ms, target_ms);
        assert!(
            nt.waited_ms > target_ms,
            "request {} shed at {:.1} ms — inside its {target_ms} ms deadline",
            nt.id,
            nt.waited_ms
        );
    }
    for &(id, admitted_tick) in &completions {
        let wait_ms = wait_at(admitted_tick);
        assert!(
            wait_ms <= target_ms,
            "request {id} was admitted {wait_ms:.1} ms after submission — the \
             pre-admission sweep should have shed it at {target_ms} ms"
        );
    }
}

#[test]
fn queue_action_preserves_bounded_wait_under_sustained_overload() {
    // 2x sustained arrival rate (two submissions per tick against ~one
    // retirement), `SloAction::Queue`: deadlines expire on the virtual
    // clock but are observational — nothing sheds, FIFO holds, and every
    // wait stays inside the 2x-scaled pipelined bound of the fairness
    // property above.
    let slo = SloPolicy { target_ms: 5.0, action: SloAction::Queue };
    let slots = 2usize;
    let n = 16u64;
    let max_new_max = 6usize;
    let mut bk = SimBackend::new(90);
    let mut engines: Vec<Engine> =
        (0..slots).map(|_| Engine::new(&bk, base_cfg())).collect();
    let cap = bk.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(slots, cap);
    sched.set_pipelining(base_cfg().pipelining);
    let mut next = 0u64;
    let mut waited: Vec<u64> = Vec::new();
    let mut safety = 0u32;
    while waited.len() < n as usize {
        // 2x the drain rate: two fresh submissions per tick until spent
        for _ in 0..2 {
            if next < n {
                sched.submit(SlotRequest {
                    id: next,
                    prompt: prompt(8, 700 + next),
                    max_new: 1 + (next as usize % max_new_max),
                    cfg: None,
                    slo: Some(slo),
                });
                next += 1;
            }
        }
        sched
            .tick(&mut bk, &mut engines, &mut |c: Completion| {
                waited.push(c.waited_ticks);
                Disposition::Release
            })
            .unwrap();
        sched.advance_clock(10.0); // every queued deadline is long expired
        safety += 1;
        assert!(safety < 10_000, "queue-overload drive failed to converge");
    }
    assert_eq!(sched.stats.shed, 0, "queue-action deadlines must never shed");
    assert!(sched.drain_shed().is_empty());
    let bound = ((n / slots as u64) + 2) * 2 * (max_new_max as u64 + 2);
    for (i, w) in waited.iter().enumerate() {
        assert!(*w <= bound, "completion {i} waited {w} ticks (> bound {bound})");
    }
}

#[test]
fn abort_all_recovers_mid_overload() {
    // Abort a shedding, overloaded scheduler mid-flight: the queue, the
    // slots, and the per-slot SLO table must all clear, and a fresh
    // submission afterwards must decode exactly like a sequential engine.
    let slo = SloPolicy { target_ms: 15.0, action: SloAction::Shed };
    let mut bk = SimBackend::new(90);
    let mut engines: Vec<Engine> =
        (0..2).map(|_| Engine::new(&bk, base_cfg())).collect();
    let cap = bk.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(2, cap);
    sched.set_pipelining(base_cfg().pipelining);
    for i in 0..12u64 {
        sched.submit(SlotRequest {
            id: i,
            prompt: prompt(8, 800 + i),
            max_new: 8,
            cfg: None,
            slo: Some(slo),
        });
    }
    // a few overloaded ticks: some shed, some decode, some still in flight
    for _ in 0..3 {
        sched
            .tick(&mut bk, &mut engines, &mut |_c: Completion| Disposition::Release)
            .unwrap();
        sched.advance_clock(10.0);
    }
    let aborted_shed = sched.abort_all();
    for e in engines.iter_mut() {
        e.reset();
    }
    assert!(sched.is_idle(), "abort_all must leave the scheduler idle");
    // abort_all hands back the undrained shed notices instead of
    // discarding them: every shed the stats counted is accounted for,
    // and the internal drain buffer is left empty.
    assert_eq!(
        aborted_shed.len() as u64,
        sched.stats.shed,
        "abort_all must surface exactly the sheds the stats counted"
    );
    assert!(
        sched.drain_shed().is_empty(),
        "abort_all must leave no shed notices behind for a later drain"
    );

    // recovery: a fresh post-abort request decodes bit-identically to a
    // dedicated sequential engine, unburdened by any stale SLO state
    let p = prompt(12, 901);
    let want = {
        let mut b = SimBackend::new(90);
        let mut e = Engine::new(&b, base_cfg());
        e.generate_speculative(&mut b, &p, 10).unwrap().tokens
    };
    sched.submit(SlotRequest { id: 99, prompt: p, max_new: 10, cfg: None, slo: None });
    let mut got: Option<Vec<i32>> = None;
    sched
        .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
            assert_eq!(c.id, 99);
            assert_eq!(c.slo, None, "aborted SLOs must not leak onto new requests");
            got = Some(c.out.tokens);
            Disposition::Release
        })
        .unwrap();
    assert_eq!(got.as_deref(), Some(&want[..]), "post-abort decode diverged");
    // the frozen clock afterwards keeps the no-SLO path untouched
    assert_eq!(sched.drain_shed().len(), 0);
}
