//! Acceptance properties of the multi-worker serving split
//! (coordinator front end + N engine workers over typed channel RPC):
//!
//! 1. **Worker-count invisibility** — every conversation's token stream
//!    is a function of the trace alone: `--workers N` for N ∈ {1, 2, 4}
//!    produces bit-identical per-conversation tokens, all equal to a
//!    dedicated sequential engine decoding the same turns (park/resume
//!    churn included).
//! 2. **Determinism** — a multi-worker replay of the same trace twice
//!    yields bit-identical records and percentiles.
//! 3. **Consistent-hash routing** — the ring is deterministic, covers
//!    every rank, and growing the worker count remaps only part of the
//!    id space.
//! 4. **Shed accounting across shutdown** — shed notices raised after
//!    the coordinator stopped reading per-tick events ride the final
//!    `WorkerStats` drain handshake instead of being silently dropped
//!    (the `abort_all` regression).
//!
//! The `EA_WORKERS` environment variable (CI axis) adds one more worker
//! count to the identity sweep, so the whole suite exercises the
//! topology CI selects.

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::config::RunConfig;
use eagle_pangu::coordinator::{
    followup_prompt, run_worker, BackendSpec, HashRing, SloAction, SloPolicy, WorkerConfig,
};
use eagle_pangu::engine::Engine;
use eagle_pangu::harness::{replay, ReplayConfig};
use eagle_pangu::rpc::{wire_channel, Envelope, JsonCodec, RequestKind, Submit};
use eagle_pangu::util::SplitMix64;
use eagle_pangu::workload::{ArrivalKind, PromptFamily, TraceSpec};
use std::collections::BTreeSet;

/// The CI topology axis: `EA_WORKERS` adds a worker count to the sweep.
fn env_workers() -> Option<usize> {
    std::env::var("EA_WORKERS").ok().and_then(|v| v.parse().ok())
}

#[test]
fn worker_count_is_invisible_in_token_streams() {
    // Two-turn conversations with park/resume churn, replayed at every
    // worker count: per-conversation tokens must match each other and
    // the dedicated sequential reference (one fresh backend + engine
    // per conversation, turn 2 decoded on the same engine — residency).
    let trace = TraceSpec::smoke_poisson(33).generate().unwrap();
    let turns = 2;
    let mut cfg = ReplayConfig::new(3);
    cfg.turns = turns;

    let reference: Vec<Vec<i32>> = trace
        .iter()
        .map(|r| {
            let mut b = SimBackend::new(cfg.agree_pct);
            let mut e = Engine::new(&b, RunConfig::default());
            let mut all: Vec<i32> = Vec::new();
            for turn in 0..turns {
                let prompt =
                    if turn == 0 { r.prompt.clone() } else { followup_prompt(&all) };
                let out = e.generate_speculative(&mut b, &prompt, r.max_new).unwrap();
                all.extend(out.tokens);
            }
            all
        })
        .collect();

    let mut counts: BTreeSet<usize> = [1, 2, 4].into();
    counts.extend(env_workers().filter(|&w| w >= 1));
    for workers in counts {
        cfg.workers = workers;
        let rep = replay(&trace, &cfg).unwrap();
        assert_eq!(rep.completed, trace.len(), "workers={workers} must complete everything");
        assert_eq!(rep.shed, 0);
        assert_eq!(rep.stats.len(), workers);
        for ((r, rec), want) in trace.iter().zip(&rep.records).zip(&reference) {
            assert_eq!(
                &rec.tokens, want,
                "conversation {} tokens diverged at workers={workers} \
                 (the stream must be a function of the trace alone)",
                r.id
            );
        }
        // Multi-turn accounting reaches the aggregated stats.
        let parked: u64 = rep.stats.iter().map(|s| s.parked).sum();
        let resumed: u64 = rep.stats.iter().map(|s| s.resumed).sum();
        assert_eq!(parked as usize, trace.len() * (turns - 1));
        assert_eq!(resumed, parked, "every park was resumed");
    }
}

#[test]
fn multi_worker_replay_is_deterministic() {
    let trace = TraceSpec::smoke_poisson(5).generate().unwrap();
    let mut cfg = ReplayConfig::new(2);
    cfg.workers = 4;
    cfg.turns = 2;
    let r1 = replay(&trace, &cfg).unwrap();
    let r2 = replay(&trace, &cfg).unwrap();
    assert_eq!(r1.records, r2.records, "multi-worker replay must be bit-deterministic");
    assert_eq!(r1.p50_ms.to_bits(), r2.p50_ms.to_bits());
    assert_eq!(r1.p99_ms.to_bits(), r2.p99_ms.to_bits());
}

#[test]
fn hash_ring_is_stable_and_covers_every_rank() {
    let ring = HashRing::new(4);
    assert_eq!(ring.workers(), 4);
    // Deterministic: an independently built ring routes identically.
    let again = HashRing::new(4);
    let mut per_rank = vec![0usize; 4];
    for id in 0..1000u64 {
        let r = ring.route(id);
        assert_eq!(r, again.route(id), "routing must be a pure function of (workers, id)");
        assert!(r < 4);
        per_rank[r] += 1;
    }
    for (rank, n) in per_rank.iter().enumerate() {
        assert!(
            *n > 50,
            "rank {rank} owns only {n}/1000 ids — the ring spread collapsed"
        );
    }
    // Consistent hashing: growing 4 -> 5 workers remaps only part of
    // the id space (modulo sharding would remap ~80%).
    let grown = HashRing::new(5);
    let moved = (0..1000u64).filter(|&id| ring.route(id) != grown.route(id)).count();
    assert!(moved > 0, "a fifth worker must take over some ids");
    assert!(
        moved < 500,
        "consistent hashing moved {moved}/1000 ids on +1 worker (expected ~1/5)"
    );
}

#[test]
fn shard_stats_aggregate_per_rank_under_shed() {
    // Overload with a tight shed SLO across 3 workers: the per-rank
    // scheduler counters in the report must account for every shed and
    // every completion, summed across ranks.
    // The rate is sized so every shard is overloaded on its own: a
    // single queue sheds at ~10x capacity, and 2000 rps split three
    // ways still leaves each worker far past what 2 slots sustain.
    let trace = TraceSpec {
        requests: 48,
        kind: ArrivalKind::Poisson { rate_rps: 2000.0 },
        family: PromptFamily::Mixed,
        prompt_mean: 16,
        max_new: 6,
        seed: 9,
    }
    .generate()
    .unwrap();
    let mut cfg = ReplayConfig::new(2);
    cfg.workers = 3;
    cfg.slo = Some(SloPolicy { target_ms: 10.0, action: SloAction::Shed });
    let rep = replay(&trace, &cfg).unwrap();
    assert_eq!(rep.stats.len(), 3);
    assert!(rep.shed > 0, "overload far beyond capacity must shed something");
    let shed: u64 = rep.stats.iter().map(|s| s.shed).sum();
    let retired: u64 = rep.stats.iter().map(|s| s.retired).sum();
    assert_eq!(shed as usize, rep.shed, "per-rank shed counters must sum to the shed count");
    assert_eq!(retired as usize, rep.completed, "per-rank retire counters must sum up");
    for rec in &rep.records {
        assert_eq!(rec.tokens.is_empty(), rec.shed, "served iff it streamed tokens");
    }
}

fn prompt(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = SplitMix64::new(seed);
    let mut p = vec![1i32];
    for _ in 1..n.max(2) {
        p.push(rng.range(2, 512) as i32);
    }
    p
}

#[test]
fn sheds_raised_after_shutdown_surface_in_final_stats() {
    // The abort_all regression, end to end: a worker whose coordinator
    // hangs up mid-batch still holds shed notices its scheduler raised
    // but never got to drain (batch-end shed events were never reached).
    // They must arrive in the final WorkerStats drain handshake — the
    // old code path dropped them with the scheduler epoch.
    let (cmd_tx, cmd_rx) = wire_channel::<Envelope, JsonCodec>(64);
    let (event_tx, event_rx) = wire_channel::<Envelope, JsonCodec>(256);
    let cfg = WorkerConfig {
        rank: 0,
        slots: 2,
        backend: BackendSpec::Sim { agree_pct: 90 },
        run: RunConfig::default(),
        tick_host_ms: 1.0,
        launch_ms: 2.0,
    };
    let handle = std::thread::spawn(move || run_worker::<JsonCodec>(cfg, cmd_rx, event_tx));

    // 12 simultaneous arrivals onto 2 slots. FIFO admission seats the
    // two long park-on-complete conversations; the other ten queue with
    // a 1 ms shed deadline no later tick can meet, so they all shed
    // well before the first park (a 24-token turn runs many ticks).
    let n = 12u64;
    for i in 0..n {
        let long = i < 2;
        let s = Submit {
            id: i,
            prompt: prompt(6 + i as usize % 3, 4000 + i),
            max_new: if long { 24 } else { 4 },
            arrival_ms: 0.0,
            kind: RequestKind::Ea,
            park_on_complete: long,
            slo: if long {
                None
            } else {
                Some(SloPolicy { target_ms: 1.0, action: SloAction::Shed })
            },
            last: i == n - 1,
            isolated: false,
        };
        cmd_tx.send(&Envelope::Submit(s)).unwrap();
    }

    // Wait for the first Park — the worker now blocks on a Resume that
    // will never come. No shed may have been *streamed* yet: mid-batch,
    // notices only accumulate in the scheduler.
    loop {
        match event_rx.recv().unwrap() {
            Envelope::Park(_) => break,
            Envelope::TokenDelta(_) => {}
            Envelope::ShedNotice(sn) => {
                panic!("mid-batch shed notice for {} streamed early", sn.notice.id)
            }
            other => panic!("unexpected '{}' before the first park", other.kind_str()),
        }
    }
    // Hang up instead of resuming: the worker aborts its epoch and must
    // fold the ten undrained sheds into its final stats message.
    drop(cmd_tx);
    let ws = loop {
        match event_rx.recv().unwrap() {
            Envelope::WorkerStats(ws) => break ws,
            Envelope::Park(_) | Envelope::TokenDelta(_) => {}
            other => panic!("unexpected '{}' while draining", other.kind_str()),
        }
    };
    handle.join().unwrap();
    assert!(ws.is_final, "the drain handshake is flagged final");
    assert_eq!(ws.error, None, "hangup is a clean shutdown, not a failure");
    assert_eq!(ws.stats.shed, 10, "all ten deadlined requests shed");
    assert_eq!(
        ws.shed.len() as u64,
        ws.stats.shed,
        "every counted shed must surface in the final stats (the abort_all regression)"
    );
    let ids: BTreeSet<u64> = ws.shed.iter().map(|s| s.id).collect();
    assert_eq!(ids, (2..12).collect::<BTreeSet<u64>>(), "exactly the queued ten shed");
}
