//! Fixture: a flag registry.
pub const TOGGLE_FLAGS: &[&str] = &["pipelining"];
const VALUED: &[&str] = &[
    "seed", "workers",
];
pub fn not_a_registry() -> &'static str {
    "not-a-flag"
}
