//! Fixture: an undocumented flag waived with an audited reason.
pub const TOGGLE_FLAGS: &[&str] = &["pipelining"];
const VALUED: &[&str] = &[
    "seed",
    "workers", // lint: allow(flag-doc) — internal debugging flag, deliberately undocumented
];
