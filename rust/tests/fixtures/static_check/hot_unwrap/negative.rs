//! Fixture: typed-error shapes; unwrap-family combinators are fine.
pub fn take(opt: Option<u32>) -> Result<u32, String> {
    let a = opt.unwrap_or(0);
    let Some(b) = opt else {
        return Err("empty".to_string());
    };
    let s = ".unwrap() in a string";
    let _ = s;
    Ok(a + b)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_ok_in_tests() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}
