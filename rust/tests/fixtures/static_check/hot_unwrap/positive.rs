//! Fixture: panicking extraction on the serve path.
pub fn take(opt: Option<u32>, res: Result<u32, String>) -> u32 {
    let a = opt.unwrap();
    let b = res.expect("boom");
    a + b
}
