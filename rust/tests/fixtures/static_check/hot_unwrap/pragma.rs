//! Fixture: a waived lock-poisoning expect with an audited reason.
use std::sync::{Mutex, MutexGuard};

pub fn lock(m: &Mutex<u32>) -> MutexGuard<'_, u32> {
    // lint: allow(hot-unwrap) — poisoning means a sibling panicked mid-mutation; propagate it
    m.lock().expect("lock poisoned")
}
