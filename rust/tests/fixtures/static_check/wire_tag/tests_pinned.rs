//! Fixture: the tag-pinning test file — both tags appear as literals.
const TAGS: &[&str] = &["submit", "abort"];
