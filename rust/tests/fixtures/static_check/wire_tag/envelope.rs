//! Fixture: a two-variant envelope with distinct tags.
pub enum Envelope {
    Submit(u32),
    Abort(u32),
}

impl Envelope {
    pub fn kind_str(&self) -> &'static str {
        match self {
            Envelope::Submit(_) => "submit",
            Envelope::Abort(_) => "abort",
        }
    }
}
