//! Fixture: the tag-pinning test file with "abort" missing.
const TAGS: &[&str] = &["submit"];
