//! Fixture: an unpinned tag waived with an audited reason.
pub enum Envelope {
    Submit(u32),
    Abort(u32),
}

impl Envelope {
    pub fn kind_str(&self) -> &'static str {
        match self {
            Envelope::Submit(_) => "submit",
            // lint: allow(wire-tag) — tag lands in tests/rpc.rs with the codec PR
            Envelope::Abort(_) => "abort",
        }
    }
}
