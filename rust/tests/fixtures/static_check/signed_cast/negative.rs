//! Fixture: named casts only; `as usize` survives in strings and tests.
use crate::util::idx::udx;

pub fn pick(v: &[f32], idx: u32) -> f32 {
    let label = "idx as usize";
    let _ = label;
    v[udx(idx)]
}

#[cfg(test)]
mod tests {
    #[test]
    fn raw_cast_ok_in_tests() {
        let v = [1.0f32];
        let i: u32 = 0;
        assert_eq!(v[i as usize], 1.0);
    }
}
