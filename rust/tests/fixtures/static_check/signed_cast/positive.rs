//! Fixture: a raw `as usize` on an index path.
pub fn pick(v: &[f32], idx: i64) -> f32 {
    v[idx as usize]
}
