//! Fixture: a waived cast with an audited reason.
pub fn pick(v: &[f32], idx: u32) -> f32 {
    v[idx as usize] // lint: allow(signed-cast) — u32 source, widening is lossless
}
