//! Fixture: an unsafe impl in library source.
pub struct X(*mut u8);
unsafe impl Send for X {}
