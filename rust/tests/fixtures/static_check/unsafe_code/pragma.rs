//! Fixture: a waived unsafe token with an audited reason.
// lint: allow(unsafe-code) — alloc-shim fixture; real shims live in tests/support/
unsafe impl Send for Y {}
pub struct Y(*mut u8);
