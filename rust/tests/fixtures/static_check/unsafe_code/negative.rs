//! Fixture: `unsafe` as an identifier fragment or string is not the token.
#![forbid(unsafe_code)]

pub fn unsafe_code_rule_name() -> &'static str {
    "unsafe in a string"
}
