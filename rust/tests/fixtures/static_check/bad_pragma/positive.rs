//! Fixture: malformed waivers — reasonless, and an unknown rule id.
pub fn f() {}
// lint: allow(wall-clock)
// lint: allow(not-a-rule) — a reason cannot save an unknown id
