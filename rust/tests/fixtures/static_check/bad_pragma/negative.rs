//! Fixture: a well-formed waiver (waiving nothing is not an error).
pub fn f() {}
// lint: allow(hot-unwrap) — documented panic policy
