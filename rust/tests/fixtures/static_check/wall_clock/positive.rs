//! Fixture: a raw wall-clock read in scheduler-adjacent code.
use std::time::Instant;

pub fn tick() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
