//! Fixture: the clean shape — Stopwatch for measurement, wall-clock
//! reads only mentioned in prose, strings, and test code.
use crate::util::timer::Stopwatch;

/// Mentions Instant::now in a doc comment only.
pub fn tick() -> f64 {
    let t0 = Stopwatch::start();
    let s = "Instant::now is just a string here";
    let _ = s;
    t0.elapsed_secs()
}

#[cfg(test)]
mod tests {
    #[test]
    fn bench_like() {
        let t0 = std::time::Instant::now();
        let _ = t0.elapsed();
    }
}
