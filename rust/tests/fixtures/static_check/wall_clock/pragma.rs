//! Fixture: a waived wall-clock read with an audited reason.
use std::time::Instant;

pub fn deadline_spin(deadline: Instant) {
    // lint: allow(wall-clock) — modeled device clock needs future-deadline comparison
    while Instant::now() < deadline {
        std::hint::spin_loop();
    }
}
