# Fixture: a module-name f-string that does not round-trip ModuleKey.
def export(s):
    modules["teacher_fussed_s{s}".format(s=s)] = 1
    modules[f"kv_append_coach_n{s}"] = 1
