# Fixture: a deliberately off-schema name with an audited reason.
def export(s):
    # lint: allow(artifact-drift) — experimental module, loader support lands next PR
    modules[f"teacher_fussed_s{s}"] = 1
