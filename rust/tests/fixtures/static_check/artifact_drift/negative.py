# Fixture: valid schema names plus non-candidate strings.
"""Docstring mentioning teacher_fussed_s8 must not trip the rule."""
def export(s, b, n):
    modules[f"teacher_fused_s{s}"] = 1
    modules[f"teacher_fused_b{b}_s{s}"] = 1
    modules[f"draft_s{s}"] = 1
    modules[f"draft_probe_s{s}"] = 1
    modules[f"kv_append_draft_n{n}"] = 1
    role = "teacher"
    key = "teacher_s_variants"
    weights = "weights_teacher.npz"
    return role, key, weights
