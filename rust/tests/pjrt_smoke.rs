//! PJRT integration smoke tests: the rust runtime against the real AOT
//! artifacts, verified bit-for-bit-ish against python-recorded goldens.
//!
//! Skipped (with a loud message) when `artifacts/` has not been built —
//! run `make artifacts` first.

use eagle_pangu::backend::ModelBackend;
use eagle_pangu::config::ExecMode;
use eagle_pangu::engine::Engine;
use eagle_pangu::config::RunConfig;
use eagle_pangu::runtime::golden::{load_goldens, verify_golden};
use eagle_pangu::runtime::PjrtBackend;
use std::path::PathBuf;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn goldens_match_python_outputs() {
    let Some(dir) = artifact_dir() else { return };
    let mut backend = PjrtBackend::load(&dir).expect("load artifacts");
    let goldens = load_goldens(&dir).expect("golden.json");
    assert_eq!(goldens.len(), 3);
    for rec in &goldens {
        verify_golden(&mut backend, rec).unwrap_or_else(|e| panic!("{e:#}"));
    }
}

#[test]
fn fused_and_eager_artifacts_agree_on_goldens() {
    // The two-mode protocol: both execution paths must produce the same
    // numerics on the same inputs (the eager path is the reference).
    let Some(dir) = artifact_dir() else { return };
    let mut backend = PjrtBackend::load(&dir).expect("load artifacts");
    use eagle_pangu::backend::{KvView, StepArgs, StepScratch};
    use eagle_pangu::runtime::golden::golden_inputs;
    let contract = backend.contract().clone();
    let gi = golden_inputs(&contract, "teacher");
    let run = |b: &mut PjrtBackend, mode: ExecMode| {
        let mut out = StepScratch::new();
        b.teacher_step(mode, StepArgs {
            tokens: &gi.tokens,
            positions: &gi.positions,
            mask: &gi.mask,
            kv: KvView::flat(&gi.k_cache, &gi.v_cache, contract.cache_cap),
            feats_in: None,
            probe: false,
            session: None,
        }, &mut out)
        .unwrap();
        out
    };
    let f = run(&mut backend, ExecMode::Fused);
    let e = run(&mut backend, ExecMode::Eager);
    let max_diff = f
        .logits
        .iter()
        .zip(&e.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "fused vs eager logits diverge: {max_diff}");
}

#[test]
fn end_to_end_speculative_decode_on_real_model() {
    // Tiny end-to-end: EA and baseline decode the same grammar prompt on
    // the real artifacts; greedy equivalence must hold on real numerics.
    let Some(dir) = artifact_dir() else { return };
    use eagle_pangu::workload::grammar::Grammar;
    let prompt = Grammar::code().sample_sequence(24, 42, None);

    let mut b1 = PjrtBackend::load(&dir).expect("load");
    let mut cfg = RunConfig::default();
    cfg.max_new_tokens = 24;
    let mut e1 = Engine::new(&b1, cfg.clone());
    let ea = e1.generate_speculative(&mut b1, &prompt, 24).expect("speculative");

    let mut b2 = PjrtBackend::load(&dir).expect("load");
    let mut e2 = Engine::new(&b2, cfg);
    let base = e2.generate_baseline(&mut b2, &prompt, ea.tokens.len()).expect("baseline");

    assert_eq!(ea.tokens, base.tokens, "EA must reproduce teacher-greedy output");
    assert!(ea.mean_accept_len() > 0.3, "trained draft should earn accepts: {}",
            ea.mean_accept_len());
    assert!(ea.teacher_calls < base.teacher_calls);
}
