//! Batched-vs-sequential bit-equality: the acceptance property of the
//! batching contract (`docs/ARCHITECTURE.md`).
//!
//! For random ragged batches — B in 1..=8 engines with mixed tree
//! budgets (hence mixed padded S variants inside one fused launch),
//! mixed prompt lengths (mixed committed context), mixed `max_new`
//! including one-token stragglers, optional drafter windows and adaptive
//! budgets — decoding through the [`ContinuousScheduler`]'s fused teacher
//! launches must produce **exactly** the tokens and acceptance shapes of
//! B independent sequential `generate_speculative` runs.

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::config::{CacheLayout, CacheStrategy, CommitMode, RunConfig};
use eagle_pangu::coordinator::ContinuousScheduler;
use eagle_pangu::engine::Engine;
use eagle_pangu::util::prop;
use eagle_pangu::util::SplitMix64;

/// Base config of the CI feature matrix: `EA_CACHE_LAYOUT` (flat | paged)
/// selects the KV layout per matrix cell; unset (local runs) = flat. The
/// whole suite is layout-agnostic by the `KvStore` bit-identity contract,
/// so every property below must hold in every cell.
fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    if let Ok(v) = std::env::var("EA_CACHE_LAYOUT") {
        cfg.cache_layout = CacheLayout::parse(&v).expect("EA_CACHE_LAYOUT must be flat|paged");
    }
    cfg
}

fn prompt(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = SplitMix64::new(seed);
    let mut p = vec![1i32]; // BOS
    for _ in 1..n.max(2) {
        p.push(rng.range(2, 512) as i32);
    }
    p
}

/// One randomized request spec.
struct Req {
    cfg: RunConfig,
    prompt: Vec<i32>,
    max_new: usize,
}

fn random_request(g: &mut prop::Gen) -> Req {
    let mut cfg = base_cfg();
    cfg.tree.budget = g.usize_in(1, 33); // ragged padded variants
    cfg.tree.depth_max = g.usize_in(2, 11);
    cfg.tree.topk = g.usize_in(1, 5);
    if g.bool_p(0.2) {
        cfg.draft_window = Some(g.usize_in(4, 48));
    }
    if g.bool_p(0.2) {
        cfg.adaptive_budget = true;
    }
    if g.bool_p(0.15) {
        cfg.cache_strategy = CacheStrategy::DeepCopy;
    }
    if g.bool_p(0.25) {
        cfg.commit_mode = CommitMode::Length;
    }
    if g.bool_p(0.15) {
        cfg.fast_reorder = false;
    }
    let p_len = g.usize_in(4, 48);
    // one-token stragglers: some requests finish after a single round
    let max_new = if g.bool_p(0.25) { g.usize_in(1, 3) } else { g.usize_in(4, 25) };
    Req { cfg, prompt: prompt(p_len, g.rng.next_u64()), max_new }
}

#[test]
fn property_batched_decode_is_bit_identical_to_sequential() {
    prop::for_cases(12, 0xBA7C4ED, |g| {
        let b_count = g.usize_in(1, 9);
        let agree = *g.choose(&[0u64, 60, 85, 100]);
        let reqs: Vec<Req> = (0..b_count).map(|_| random_request(g)).collect();

        // sequential reference: one fresh backend + engine per request
        let seq: Vec<_> = reqs
            .iter()
            .map(|r| {
                let mut b = SimBackend::new(agree);
                let mut e = Engine::new(&b, r.cfg.clone());
                e.generate_speculative(&mut b, &r.prompt, r.max_new).unwrap()
            })
            .collect();

        // batched: ONE backend, B resident engines, fused verification;
        // per-request max_new exercises the manual begin/run/take path
        let mut bk = SimBackend::new(agree);
        let mut engines: Vec<Engine> =
            reqs.iter().map(|r| Engine::new(&bk, r.cfg.clone())).collect();
        for (e, r) in engines.iter_mut().zip(&reqs) {
            e.begin_speculative(&mut bk, &r.prompt, r.max_new).unwrap();
        }
        let cap = bk.contract().cache_cap;
        let max_batch = g.usize_in(1, b_count + 1);
        let mut sched = ContinuousScheduler::new(max_batch, cap);
        sched.drive(&mut bk, &mut engines).unwrap();

        for (i, (e, s)) in engines.iter_mut().zip(&seq).enumerate() {
            let out = e.take_output().unwrap();
            assert_eq!(
                out.tokens, s.tokens,
                "request {i} tokens diverged (B={b_count}, fuse={max_batch}, agree={agree})"
            );
            assert_eq!(out.accept_lens, s.accept_lens, "request {i} acceptance diverged");
            assert_eq!(out.rounds, s.rounds, "request {i} round count diverged");
            assert_eq!(out.teacher_calls, s.teacher_calls, "request {i} call accounting");
        }
    });
}

#[test]
fn batched_multi_turn_continuation_matches_sequential() {
    // Two fused turns per conversation (context carried across turns),
    // against two sequential turns on independent engines.
    let agree = 85u64;
    let cfgs = vec![base_cfg(); 3];
    let p1: Vec<Vec<i32>> = (0..3).map(|i| prompt(10 + i * 5, 500 + i as u64)).collect();
    let p2: Vec<Vec<i32>> = (0..3).map(|i| prompt(6, 600 + i as u64)).collect();

    let seq: Vec<(Vec<i32>, Vec<i32>)> = (0..3)
        .map(|i| {
            let mut b = SimBackend::new(agree);
            let mut e = Engine::new(&b, cfgs[i].clone());
            let o1 = e.generate_speculative(&mut b, &p1[i], 14).unwrap();
            let o2 = e.generate_speculative(&mut b, &p2[i], 14).unwrap();
            (o1.tokens, o2.tokens)
        })
        .collect();

    let mut bk = SimBackend::new(agree);
    let mut engines: Vec<Engine> = cfgs.iter().map(|c| Engine::new(&bk, c.clone())).collect();
    let cap = bk.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(3, cap);
    // turn 1 fused
    for (e, p) in engines.iter_mut().zip(&p1) {
        e.begin_speculative(&mut bk, p, 14).unwrap();
    }
    sched.drive(&mut bk, &mut engines).unwrap();
    let t1: Vec<Vec<i32>> =
        engines.iter_mut().map(|e| e.take_output().unwrap().tokens).collect();
    // turn 2 fused, on the live per-engine context
    for (e, p) in engines.iter_mut().zip(&p2) {
        e.begin_speculative(&mut bk, p, 14).unwrap();
    }
    sched.drive(&mut bk, &mut engines).unwrap();
    let t2: Vec<Vec<i32>> =
        engines.iter_mut().map(|e| e.take_output().unwrap().tokens).collect();

    for i in 0..3 {
        assert_eq!(t1[i], seq[i].0, "turn 1 diverged for conversation {i}");
        assert_eq!(t2[i], seq[i].1, "turn 2 diverged for conversation {i}");
    }
}
