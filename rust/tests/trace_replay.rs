//! Seeded-determinism properties of the trace-replay load harness
//! (`workload::trace` + `harness::replay`): the same seed must reproduce
//! the same arrival schedule and the same latency distribution bit for
//! bit, with no wall-clock leakage — this is what lets `bench_gate` hold
//! a hard p99 SLO floor on `BENCH_hotpath.json` without flaking.

use eagle_pangu::coordinator::{SloAction, SloPolicy};
use eagle_pangu::harness::{replay, ReplayConfig};
use eagle_pangu::workload::{ArrivalKind, PromptFamily, TraceSpec};

/// CI topology axis (mirrors `EA_CACHE_LAYOUT`/`EA_PIPELINE` in
/// `tests/continuous.rs`): `EA_WORKERS` selects the coordinator's
/// worker count — the determinism properties must hold at any world
/// size. Default 1. The overload tests below deliberately ignore it:
/// their "must shed" thresholds are calibrated to a single admission
/// queue, and sharding the same arrival rate across N workers changes
/// the load each queue sees (multi-worker shed accounting is covered in
/// `tests/multiworker.rs`).
fn replay_cfg(slots: usize) -> ReplayConfig {
    let mut cfg = ReplayConfig::new(slots);
    if let Ok(v) = std::env::var("EA_WORKERS") {
        cfg.workers = v.parse().expect("EA_WORKERS must be a positive integer");
    }
    cfg
}

#[test]
fn same_seed_gives_identical_arrivals_and_percentiles() {
    for spec in [TraceSpec::smoke_poisson(42), TraceSpec::smoke_bursty(42)] {
        let t1 = spec.generate().unwrap();
        let t2 = spec.generate().unwrap();
        assert_eq!(t1.len(), t2.len());
        for (a, b) in t1.iter().zip(&t2) {
            assert_eq!(
                a.arrival_ms.to_bits(),
                b.arrival_ms.to_bits(),
                "arrival schedule must be bit-identical across generations"
            );
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new, b.max_new);
        }
        // two full replays: identical percentiles to the last bit, and
        // identical per-request timelines (no wall-clock ever enters a
        // latency — the driver runs on the virtual device clock only)
        let r1 = replay(&t1, &replay_cfg(4)).unwrap();
        std::thread::sleep(std::time::Duration::from_millis(25));
        let r2 = replay(&t2, &replay_cfg(4)).unwrap();
        assert_eq!(r1.p50_ms.to_bits(), r2.p50_ms.to_bits(), "p50 must be deterministic");
        assert_eq!(r1.p95_ms.to_bits(), r2.p95_ms.to_bits(), "p95 must be deterministic");
        assert_eq!(r1.p99_ms.to_bits(), r2.p99_ms.to_bits(), "p99 must be deterministic");
        assert_eq!(r1.mean_ms.to_bits(), r2.mean_ms.to_bits(), "mean must be deterministic");
        assert_eq!(r1.records, r2.records, "per-request timelines must be deterministic");
        assert_eq!(r1.completed, t1.len());
        assert_eq!(r1.shed, 0);
    }
}

#[test]
fn different_seeds_move_the_distribution() {
    let a = TraceSpec::smoke_poisson(1).generate().unwrap();
    let b = TraceSpec::smoke_poisson(2).generate().unwrap();
    assert!(
        a.iter().zip(&b).any(|(x, y)| x.arrival_ms != y.arrival_ms),
        "a different seed must move the arrival schedule"
    );
}

fn overload_spec(seed: u64) -> TraceSpec {
    TraceSpec {
        requests: 32,
        kind: ArrivalKind::Poisson { rate_rps: 400.0 },
        family: PromptFamily::Mixed,
        prompt_mean: 16,
        max_new: 6,
        seed,
    }
}

#[test]
fn shed_outcomes_are_deterministic_under_overload() {
    // ~10x the sustainable rate on 2 slots with a tight shed deadline:
    // some requests must shed, and which ones shed is a pure function of
    // the trace — bit-identical across replays.
    let trace = overload_spec(9).generate().unwrap();
    let mut cfg = ReplayConfig::new(2);
    cfg.slo = Some(SloPolicy { target_ms: 20.0, action: SloAction::Shed });
    let r1 = replay(&trace, &cfg).unwrap();
    let r2 = replay(&trace, &cfg).unwrap();
    assert!(r1.shed > 0, "overload far beyond capacity must shed something");
    assert!(r1.completed > 0, "admitted requests must still complete");
    assert_eq!(r1.completed + r1.shed, r1.total, "no request may vanish");
    assert_eq!(r1.shed, r2.shed, "shed count must be deterministic");
    assert_eq!(r1.records, r2.records, "shed identity must be deterministic");
    for rec in &r1.records {
        if rec.shed {
            assert!(rec.admitted_tick.is_none(), "shed requests are never admitted");
            assert!(rec.latency_ms.is_none(), "shed requests have no completion latency");
        } else {
            let adm = rec.admitted_tick.expect("completed requests were admitted");
            assert_eq!(rec.first_token_tick, Some(adm), "first token lands on admission");
            assert!(rec.finished_tick.expect("finished") >= adm);
            assert!(rec.latency_ms.expect("latency") > 0.0);
        }
    }
}

#[test]
fn queue_action_never_sheds() {
    // The same overload with `SloAction::Queue`: deadlines expire but are
    // observational — every request completes, none shed.
    let trace = overload_spec(9).generate().unwrap();
    let mut cfg = ReplayConfig::new(2);
    cfg.slo = Some(SloPolicy { target_ms: 20.0, action: SloAction::Queue });
    let rep = replay(&trace, &cfg).unwrap();
    assert_eq!(rep.shed, 0, "queue-action deadlines must never shed");
    assert_eq!(rep.completed, rep.total);
    assert_eq!(rep.shed_rate, 0.0);
}
