//! Cross-module integration tests on the SimBackend (no artifacts needed).
use eagle_pangu::config::RunConfig;
use eagle_pangu::coordinator::{run_workload, AdmissionPolicy, BackendSpec, CoordinatorConfig};
use eagle_pangu::metrics::{pair_turns, ThroughputReport};
use eagle_pangu::workload::WorkloadSpec;

#[test]
fn coordinator_to_report_pipeline() {
    let mut run = RunConfig::default();
    run.max_new_tokens = 10;
    let dir = std::env::temp_dir().join(format!("eagle_it_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = CoordinatorConfig {
        world_size: 2,
        run,
        workload: WorkloadSpec::smoke(),
        backend: BackendSpec::Sim { agree_pct: 85 },
        trace_dir: dir.clone(),
        run_baseline: true,
        run_ea: true,
        max_batch: 1,
        scheduling: AdmissionPolicy::Continuous,
        verbose: false,
    };
    let records = run_workload(&cfg).unwrap();
    let report = ThroughputReport::from_pairs(&pair_turns(&records));
    assert_eq!(report.turns, 9);
    let _ = std::fs::remove_dir_all(&dir);
}
