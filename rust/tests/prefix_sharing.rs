//! Copy-on-write prefix sharing: the acceptance properties of
//! `--prefix-sharing` (cache adoption through the worker prefix index).
//!
//! 1. **Refcounted free-list invariant** — with a donor's blocks adopted
//!    by a second conversation, `pool.blocks == free + referenced` holds
//!    after every random operation (shared blocks count once), the donor
//!    is never corrupted by the adopter's writes (copy-on-write), and
//!    dropping everything returns every block.
//! 2. **Bit-identity** — sharing-on emits exactly the tokens of
//!    sharing-off (and of the flat layout) for every conversation of a
//!    shared-prefix workload, across strategies and the full-reorder
//!    ablation, while spending strictly fewer prefill teacher calls from
//!    the second admission on.
//! 3. **Divergence at the boundary under churn** — the full-reorder
//!    ablation writes into adopted blocks on its first commit; the copy
//!    must privatize them without touching the frozen run, while
//!    park/resume recycles the slot between turns.
//! 4. **Scheduler admission** — on a `B = 4` slot group, sharing-on
//!    strictly reduces both `prefill_teacher_calls` and the referenced
//!    KV bytes of the parked residents, with bit-identical tokens.

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::cache::{pool_read, CachePools, KvStore, PagePool, PagedCache, SharedPool};
use eagle_pangu::config::{CacheLayout, CacheStrategy, CommitMode, Dims, RunConfig};
use eagle_pangu::coordinator::{Completion, ContinuousScheduler, Disposition, SlotRequest};
use eagle_pangu::engine::Engine;
use eagle_pangu::util::prop;
use eagle_pangu::workload::SharedPrefixSpec;
use std::sync::RwLock;
use std::sync::Arc;

const DIMS: Dims = Dims { layers: 2, d_model: 8, heads: 2, d_head: 2 };
const CAP: usize = 48;
const BS: usize = 4;

/// `[L, s, H, Dh]` step block whose row r carries `base + r` everywhere.
fn block(s: usize, base: f32) -> Vec<f32> {
    let rs = DIMS.heads * DIMS.d_head;
    let mut out = vec![0.0; DIMS.layers * s * rs];
    for l in 0..DIMS.layers {
        for r in 0..s {
            for e in 0..rs {
                out[(l * s + r) * rs + e] = base + r as f32;
            }
        }
    }
    out
}

/// Apply one random cache operation, ignoring contract errors (the
/// invariant must hold whether or not the op was legal).
fn random_op(g: &mut prop::Gen, c: &mut PagedCache, val: &mut f32) {
    *val += 3.0;
    let v = *val;
    match g.usize_in(0, 7) {
        0 => {
            let n = g.usize_in(1, 7);
            let _ = c.append_committed(&block(8, v), &block(8, v), 8, n);
        }
        1 => {
            let _ = c.begin_branch();
        }
        2 => {
            let n = g.usize_in(1, 9);
            let _ = c.append_branch(&block(16, v), &block(16, v), 16, n);
        }
        3 => c.rollback(),
        4 => {
            let take = g.usize_in(0, c.branch_rows() + 1);
            let _ = c.commit_length(take);
        }
        5 => {
            let rows = c.branch_rows();
            let mut tail = Vec::new();
            for i in 0..rows {
                if g.bool_p(0.5) {
                    tail.push(i);
                }
            }
            let _ = c.commit_path_tail(&tail);
        }
        _ => {
            let view = c.len() + c.branch_rows();
            if view == 0 {
                return;
            }
            // forward keep or a reversing full reorder — the reorder
            // scatters from row 0, writing into any adopted blocks
            let path: Vec<usize> = if g.bool_p(0.5) {
                (0..view).collect()
            } else {
                (0..view).rev().collect()
            };
            let _ = c.commit_path(&path);
        }
    }
}

fn refcount_invariant(pool: &SharedPool) {
    let p = pool_read(pool);
    assert_eq!(
        p.blocks(),
        p.free_blocks() + p.referenced_blocks(),
        "refcounted free-list invariant broken: {} blocks != {} free + {} referenced",
        p.blocks(),
        p.free_blocks(),
        p.referenced_blocks()
    );
}

#[test]
fn property_refcounted_invariant_survives_shared_random_ops() {
    prop::for_cases(40, 0x51F1_D0, |g| {
        let pool = Arc::new(RwLock::new(PagePool::new(DIMS, BS)));
        // donor commits a block-aligned run and stays frozen
        let mut donor =
            PagedCache::new(DIMS, CAP, CacheStrategy::SegmentShare, true, pool.clone());
        let nblocks = g.usize_in(1, 4);
        donor
            .append_committed(&block(16, 1.0), &block(16, 1.0), 16, nblocks * BS)
            .unwrap();
        let run = donor.committed_block_run(nblocks * BS).unwrap();
        let donor_sum = donor.committed_checksum();

        // adopter maps the same blocks, then random ops diverge it
        let strategy = *g.choose(&[CacheStrategy::SegmentShare, CacheStrategy::DeepCopy]);
        let fast = g.bool_p(0.5);
        let mut adopter = PagedCache::new(DIMS, CAP, strategy, fast, pool.clone());
        adopter.adopt_shared_blocks(&run, nblocks * BS).unwrap();
        assert_eq!(pool_read(&pool).ref_count(run[0]), 2);
        refcount_invariant(&pool);

        let mut val = 100.0f32;
        for _ in 0..g.usize_in(3, 25) {
            random_op(g, &mut adopter, &mut val);
            refcount_invariant(&pool);
            assert_eq!(
                donor.committed_checksum(),
                donor_sum,
                "adopter writes leaked into the donor's frozen blocks"
            );
        }
        drop(adopter);
        refcount_invariant(&pool);
        assert_eq!(donor.committed_checksum(), donor_sum);
        drop(donor);
        let p = pool_read(&pool);
        assert_eq!(p.free_blocks(), p.blocks(), "a dropped pair must free every block");
    });
}

// ---------------------------------------------------------------------
// Engine-level: prefill skip + bit-identity
// ---------------------------------------------------------------------

fn cfg_with(layout: CacheLayout, strategy: CacheStrategy, sharing: bool) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.cache_layout = layout;
    cfg.cache_strategy = strategy;
    cfg.prefix_sharing = sharing;
    cfg
}

#[test]
fn sharing_skips_shared_prefill_with_bit_identical_tokens() {
    let spec = SharedPrefixSpec::default();
    let prompts = spec.prompts();
    for strategy in [CacheStrategy::SegmentShare, CacheStrategy::DeepCopy] {
        // flat reference (sharing is a paged-only axis; flat is ground truth)
        let mut b_flat = SimBackend::new(85);
        let flat: Vec<_> = prompts
            .iter()
            .map(|p| {
                let cfg = cfg_with(CacheLayout::Flat, strategy, false);
                let mut e = Engine::new(&b_flat, cfg);
                e.generate_speculative(&mut b_flat, p, 8).unwrap()
            })
            .collect();
        // paged, sharing off
        let mut b_off = SimBackend::new(85);
        let pools_off = CachePools::new(b_off.contract());
        let off: Vec<_> = prompts
            .iter()
            .map(|p| {
                let cfg = cfg_with(CacheLayout::Paged, strategy, false);
                let mut e = Engine::with_pools(&b_off, cfg, &pools_off);
                e.generate_speculative(&mut b_off, p, 8).unwrap()
            })
            .collect();
        // paged, sharing on — all conversations draw from one pool set
        let mut b_on = SimBackend::new(85);
        let pools_on = CachePools::new(b_on.contract());
        let on: Vec<_> = prompts
            .iter()
            .map(|p| {
                let cfg = cfg_with(CacheLayout::Paged, strategy, true);
                let mut e = Engine::with_pools(&b_on, cfg, &pools_on);
                e.generate_speculative(&mut b_on, p, 8).unwrap()
            })
            .collect();

        for i in 0..prompts.len() {
            assert_eq!(on[i].tokens, off[i].tokens, "sharing changed tokens ({strategy:?}, conv {i})");
            assert_eq!(on[i].tokens, flat[i].tokens, "paged diverged from flat ({strategy:?}, conv {i})");
            assert_eq!(on[i].accept_lens, off[i].accept_lens, "acceptance diverged ({strategy:?})");
            assert_eq!(on[i].rounds, off[i].rounds, "round count diverged ({strategy:?})");
        }
        // the first conversation seeds the index and pays full prefill
        assert_eq!(on[0].teacher_calls, off[0].teacher_calls);
        assert_eq!(on[0].teacher_cache.adopted_rows, 0);
        // every later admission adopts the resident 160-token run and
        // skips its prefill chunk: strictly fewer teacher calls
        for i in 1..prompts.len() {
            assert!(
                on[i].teacher_calls < off[i].teacher_calls,
                "conv {i} must spend fewer teacher calls sharing-on \
                 ({} vs {}, {strategy:?})",
                on[i].teacher_calls,
                off[i].teacher_calls
            );
            assert!(
                on[i].teacher_cache.adopted_rows >= spec.prefix_len as u64,
                "conv {i} must adopt at least the shared prefix ({strategy:?})"
            );
            assert_eq!(on[i].teacher_cache.adopted_rows, on[i].draft_cache.adopted_rows);
        }
    }
}

// ---------------------------------------------------------------------
// Divergence at the boundary block under park/resume churn
// ---------------------------------------------------------------------

#[test]
fn full_reorder_divergence_is_private_under_park_resume_churn() {
    // fast_reorder=false + path-index commits: every commit rewrites the
    // sequence from row 0, so an adopter's first commit writes straight
    // into its adopted blocks — the CoW divergence vector. A parked
    // donor must survive two such siblings recycling its slot, then
    // resume its second turn bit-identically.
    let mk_cfg = |sharing: bool| {
        let mut cfg = cfg_with(CacheLayout::Paged, CacheStrategy::SegmentShare, sharing);
        cfg.commit_mode = CommitMode::PathIndex;
        cfg.fast_reorder = false;
        cfg
    };
    let spec = SharedPrefixSpec { conversations: 3, ..SharedPrefixSpec::default() };
    let prompts = spec.prompts();
    let turn2: Vec<i32> = (2..14).collect();

    // sharing-off references: dedicated engine per conversation, plus a
    // dedicated two-turn engine for conversation 0
    let mut b_ref = SimBackend::new(85);
    let want: Vec<_> = prompts
        .iter()
        .map(|p| {
            let mut e = Engine::new(&b_ref, mk_cfg(false));
            e.generate_speculative(&mut b_ref, p, 8).unwrap()
        })
        .collect();
    let mut b2 = SimBackend::new(85);
    let mut e2 = Engine::new(&b2, mk_cfg(false));
    let w1 = e2.generate_speculative(&mut b2, &prompts[0], 8).unwrap();
    let w2 = e2.generate_speculative(&mut b2, &turn2, 8).unwrap();
    assert_eq!(w1.tokens, want[0].tokens);

    // sharing-on: one slot engine serves everything
    let mut bk = SimBackend::new(85);
    let pools = CachePools::new(bk.contract());
    let mut slot = Engine::with_pools(&bk, mk_cfg(true), &pools);
    let g1 = slot.generate_speculative(&mut bk, &prompts[0], 8).unwrap();
    assert_eq!(g1.tokens, w1.tokens, "donor turn 1 diverged");
    let parked = slot.park().unwrap();

    // churn: siblings adopt the frozen run on the freed slot and
    // immediately diverge into it via full reorders
    for i in 1..prompts.len() {
        let g = slot.generate_speculative(&mut bk, &prompts[i], 8).unwrap();
        assert_eq!(g.tokens, want[i].tokens, "sibling {i} diverged");
        assert!(
            g.teacher_cache.adopted_rows >= spec.prefix_len as u64,
            "sibling {i} must adopt the shared run"
        );
        assert!(
            g.teacher_cache.cow_copies > 0,
            "a full reorder into adopted blocks must copy-on-write"
        );
        slot.reset();
    }
    refcount_invariant(&pools.teacher);
    refcount_invariant(&pools.draft);

    // the donor resumes turn 2 on its preserved context
    slot.resume(parked).unwrap();
    let g2 = slot.generate_speculative(&mut bk, &turn2, 8).unwrap();
    assert_eq!(g2.tokens, w2.tokens, "resumed donor turn diverged after sibling churn");
    assert_eq!(
        g2.teacher_calls, w2.teacher_calls,
        "resume must not re-prefill the parked context"
    );
}

// ---------------------------------------------------------------------
// Scheduler admission at B = 4
// ---------------------------------------------------------------------

#[test]
fn scheduler_admission_shares_prefill_and_residency_at_b4() {
    let spec = SharedPrefixSpec::default();
    let prompts = spec.prompts();
    // run the workload through a 4-slot group, parking every retired
    // conversation so the final residency is the full resident set
    let run = |sharing: bool| -> (Vec<Vec<i32>>, u64, u64, u64) {
        let mut bk = SimBackend::new(85);
        let pools = CachePools::new(bk.contract());
        let cap = bk.contract().cache_cap;
        let cfg = cfg_with(CacheLayout::Paged, CacheStrategy::SegmentShare, sharing);
        let mut engines: Vec<Engine> =
            (0..4).map(|_| Engine::with_pools(&bk, cfg.clone(), &pools)).collect();
        let mut sched = ContinuousScheduler::new(4, cap);
        for (i, p) in prompts.iter().enumerate() {
            sched.submit(SlotRequest {
                id: i as u64,
                prompt: p.clone(),
                max_new: 6,
                cfg: None,
                slo: None,
            });
        }
        let mut outs = vec![Vec::new(); prompts.len()];
        let mut adopted = 0u64;
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
                outs[c.id as usize] = c.out.tokens.clone();
                adopted += c.out.teacher_cache.adopted_rows;
                Disposition::Park
            })
            .unwrap();
        assert_eq!(sched.parked_count(), prompts.len());
        refcount_invariant(&pools.teacher);
        refcount_invariant(&pools.draft);
        (outs, sched.stats.prefill_teacher_calls, pools.referenced_bytes(), adopted)
    };
    let (on_toks, on_calls, on_bytes, on_adopted) = run(true);
    let (off_toks, off_calls, off_bytes, off_adopted) = run(false);
    assert_eq!(on_toks, off_toks, "sharing must not change any conversation's tokens");
    assert!(
        on_calls < off_calls,
        "sharing-on must spend fewer prefill teacher calls ({on_calls} vs {off_calls})"
    );
    assert!(
        on_bytes < off_bytes,
        "sharing-on must keep fewer KV bytes resident ({on_bytes} vs {off_bytes})"
    );
    assert_eq!(off_adopted, 0, "sharing-off must adopt nothing");
    assert!(
        on_adopted >= (prompts.len() as u64 - 1) * spec.prefix_len as u64,
        "every admission after the first must adopt the shared run"
    );
}
