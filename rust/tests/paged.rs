//! Paged-vs-flat bit-identity: the acceptance property of the paged KV
//! cache (`cache/paged.rs`).
//!
//! 1. **Cache-level equivalence** — for random operation sequences
//!    (append/branch/append_branch/rollback/commit_length/
//!    commit_path/commit_path_tail/reset) over both strategies, a
//!    [`PagedCache`] and a [`ManagedCache`] driven identically hold
//!    bit-identical committed state (`committed_checksum` +
//!    `committed_row_k`), including with a *second* resident cache
//!    interleaving its own sequence on the same pool (the park shape:
//!    one conversation's blocks survive untouched while another maps and
//!    frees its own).
//! 2. **Free-list invariant** — after every operation,
//!    `pool.blocks == pool.free + Σ mapped(live caches)`: no leak, no
//!    double-free.
//! 3. **Engine-level equivalence** — `cache_layout: Paged` decodes
//!    bit-identically to `Flat` across strategies/commit modes, and
//!    scheduler park/resume continues a multi-turn conversation exactly
//!    like a dedicated engine (no re-prefill).

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::cache::{pool_read, KvStore, ManagedCache, PagePool, PagedCache, SharedPool};
use eagle_pangu::config::{CacheLayout, CacheStrategy, CommitMode, Dims, RunConfig};
use eagle_pangu::coordinator::{Completion, ContinuousScheduler, Disposition, SlotRequest};
use eagle_pangu::engine::{Engine, GenOut};
use eagle_pangu::util::prop;
use eagle_pangu::util::SplitMix64;
use std::sync::RwLock;
use std::sync::Arc;

const DIMS: Dims = Dims { layers: 2, d_model: 8, heads: 2, d_head: 2 };
const CAP: usize = 48;
const BS: usize = 4;

/// `[L, s, H, Dh]` step block whose row r carries `base + r` everywhere.
fn block(s: usize, base: f32) -> Vec<f32> {
    let rs = DIMS.heads * DIMS.d_head;
    let mut out = vec![0.0; DIMS.layers * s * rs];
    for l in 0..DIMS.layers {
        for r in 0..s {
            for e in 0..rs {
                out[(l * s + r) * rs + e] = base + r as f32;
            }
        }
    }
    out
}

/// One twinned cache pair driven through identical operations.
struct Twin {
    flat: ManagedCache,
    paged: PagedCache,
    val: f32,
}

impl Twin {
    fn new(strategy: CacheStrategy, fast: bool, pool: &SharedPool) -> Self {
        Twin {
            flat: ManagedCache::new(DIMS, CAP, strategy, fast),
            paged: PagedCache::new(DIMS, CAP, strategy, fast, pool.clone()),
            val: 1.0,
        }
    }

    /// Apply one random operation to both caches; results (incl. errors)
    /// must agree.
    fn step(&mut self, g: &mut prop::Gen) {
        self.val += 7.0;
        let v = self.val;
        match g.usize_in(0, 7) {
            0 => {
                let n = g.usize_in(1, 7);
                let a = KvStore::append_committed(&mut self.flat, &block(8, v), &block(8, v), 8, n);
                let b = self.paged.append_committed(&block(8, v), &block(8, v), 8, n);
                assert_eq!(a.is_ok(), b.is_ok(), "append_committed outcome diverged");
            }
            1 => {
                let a = KvStore::begin_branch(&mut self.flat);
                let b = self.paged.begin_branch();
                assert_eq!(a.is_ok(), b.is_ok(), "begin_branch outcome diverged");
            }
            2 => {
                let n = g.usize_in(1, 9);
                let a = KvStore::append_branch(&mut self.flat, &block(16, v), &block(16, v), 16, n);
                let b = self.paged.append_branch(&block(16, v), &block(16, v), 16, n);
                assert_eq!(a.is_ok(), b.is_ok(), "append_branch outcome diverged");
            }
            3 => {
                KvStore::rollback(&mut self.flat);
                self.paged.rollback();
            }
            4 => {
                let a_rows = KvStore::branch_rows(&self.flat);
                let take = g.usize_in(0, a_rows + 2);
                let a = KvStore::commit_length(&mut self.flat, take);
                let b = self.paged.commit_length(take);
                assert_eq!(a.is_ok(), b.is_ok(), "commit_length outcome diverged");
            }
            5 => {
                // random strictly-increasing subset of branch rows
                let rows = KvStore::branch_rows(&self.flat);
                let mut tail = Vec::new();
                for i in 0..rows {
                    if g.bool_p(0.5) {
                        tail.push(i);
                    }
                }
                let a = KvStore::commit_path_tail(&mut self.flat, &tail);
                let b = self.paged.commit_path_tail(&tail);
                assert_eq!(a.is_ok(), b.is_ok(), "commit_path_tail outcome diverged");
            }
            _ => {
                // path commit over the branch view: keep the committed
                // prefix with probability 0.7 (fast path), else a shuffled
                // full reorder (fallback path)
                let len = KvStore::len(&self.flat);
                let rows = KvStore::branch_rows(&self.flat);
                let view = len + rows;
                if view == 0 {
                    return;
                }
                let mut path: Vec<usize> = if g.bool_p(0.7) {
                    let mut p: Vec<usize> = (0..len).collect();
                    for i in 0..rows {
                        if g.bool_p(0.6) {
                            p.push(len + i);
                        }
                    }
                    p
                } else {
                    (0..view).rev().collect()
                };
                if path.is_empty() {
                    path.push(0);
                }
                let a = KvStore::commit_path(&mut self.flat, &path);
                let b = self.paged.commit_path(&path);
                assert_eq!(a.is_ok(), b.is_ok(), "commit_path outcome diverged");
            }
        }
        self.check();
    }

    /// Committed state must be bit-identical.
    fn check(&self) {
        assert_eq!(KvStore::len(&self.flat), self.paged.len(), "committed length diverged");
        assert_eq!(
            KvStore::committed_checksum(&self.flat),
            self.paged.committed_checksum(),
            "committed checksum diverged at len {}",
            self.paged.len()
        );
        for r in 0..self.paged.len() {
            assert_eq!(
                KvStore::committed_row_k(&self.flat, r),
                self.paged.committed_row_k(r),
                "committed row {r} diverged"
            );
        }
    }
}

fn pool_invariant(pool: &SharedPool, caches: &[&PagedCache]) {
    let p = pool_read(pool);
    // refcounted form: shared blocks count once however many tables map
    // them; without sharing, referenced == Σ mapped (checked both ways)
    assert_eq!(
        p.blocks(),
        p.free_blocks() + p.referenced_blocks(),
        "free-list invariant broken: {} blocks != {} free + {} referenced",
        p.blocks(),
        p.free_blocks(),
        p.referenced_blocks()
    );
    let mapped: usize = caches.iter().map(|c| c.mapped_blocks()).sum();
    assert_eq!(p.referenced_blocks(), mapped, "unshared caches must map blocks 1:1");
}

#[test]
fn property_paged_cache_is_bit_identical_to_flat() {
    prop::for_cases(60, 0x9A6E_D0, |g| {
        let pool = Arc::new(RwLock::new(PagePool::new(DIMS, BS)));
        let strategy = *g.choose(&[CacheStrategy::SegmentShare, CacheStrategy::DeepCopy]);
        let fast = g.bool_p(0.7);
        let mut twin = Twin::new(strategy, fast, &pool);
        for _ in 0..g.usize_in(5, 40) {
            twin.step(g);
            pool_invariant(&pool, &[&twin.paged]);
        }
        // reset is part of the contract too: both go back to empty and
        // the paged cache returns every block
        KvStore::reset(&mut twin.flat);
        twin.paged.reset();
        twin.check();
        assert_eq!(twin.paged.mapped_blocks(), 0);
        pool_invariant(&pool, &[&twin.paged]);
    });
}

#[test]
fn property_parked_resident_survives_sibling_traffic() {
    // The park shape at cache level: conversation A runs some ops, then
    // "parks" (sits untouched) while conversation B runs a full random
    // sequence on the SAME pool (mapping and freeing blocks); A must
    // resume with bit-identical committed state, and the pool must
    // account every block throughout.
    prop::for_cases(40, 0x9A6E_D1, |g| {
        let pool = Arc::new(RwLock::new(PagePool::new(DIMS, BS)));
        let strategy = *g.choose(&[CacheStrategy::SegmentShare, CacheStrategy::DeepCopy]);
        let mut a = Twin::new(strategy, true, &pool);
        let mut b = Twin::new(strategy, true, &pool);
        for _ in 0..g.usize_in(3, 12) {
            a.step(g);
        }
        // only park between branches: roll back any open branch first
        // (parking mid-branch is not part of the slot lifecycle)
        KvStore::rollback(&mut a.flat);
        a.paged.rollback();
        a.check();
        let parked_checksum = a.paged.committed_checksum();
        let parked_len = a.paged.len();
        // sibling traffic on the same pool
        for _ in 0..g.usize_in(5, 30) {
            b.step(g);
            pool_invariant(&pool, &[&a.paged, &b.paged]);
        }
        // B retires: its blocks return to the pool
        KvStore::reset(&mut b.flat);
        b.paged.reset();
        pool_invariant(&pool, &[&a.paged, &b.paged]);
        // A resumes untouched and keeps operating correctly
        assert_eq!(a.paged.len(), parked_len, "parked length changed");
        assert_eq!(
            a.paged.committed_checksum(),
            parked_checksum,
            "parked conversation corrupted by sibling traffic"
        );
        for _ in 0..g.usize_in(2, 10) {
            a.step(g);
            pool_invariant(&pool, &[&a.paged, &b.paged]);
        }
    });
}

// ---------------------------------------------------------------------
// Engine-level equivalence
// ---------------------------------------------------------------------

fn prompt(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = SplitMix64::new(seed);
    let mut p = vec![1i32]; // BOS
    for _ in 1..n.max(2) {
        p.push(rng.range(2, 512) as i32);
    }
    p
}

fn run_layout(cfg: &RunConfig, p: &[i32], max_new: usize, agree: u64) -> GenOut {
    let mut b = SimBackend::new(agree);
    let mut e = Engine::new(&b, cfg.clone());
    e.generate_speculative(&mut b, p, max_new).unwrap()
}

#[test]
fn paged_engine_decodes_bit_identical_to_flat() {
    let p = prompt(17, 11);
    for strategy in [CacheStrategy::SegmentShare, CacheStrategy::DeepCopy] {
        for commit in [CommitMode::PathIndex, CommitMode::Length] {
            for fast in [true, false] {
                for agree in [0u64, 85, 100] {
                    let mut cfg = RunConfig::default();
                    cfg.cache_strategy = strategy;
                    cfg.commit_mode = commit;
                    cfg.fast_reorder = fast;
                    cfg.cache_layout = CacheLayout::Flat;
                    let flat = run_layout(&cfg, &p, 24, agree);
                    cfg.cache_layout = CacheLayout::Paged;
                    let paged = run_layout(&cfg, &p, 24, agree);
                    assert_eq!(
                        flat.tokens, paged.tokens,
                        "tokens diverged: {strategy:?}/{commit:?}/fast={fast}/agree={agree}"
                    );
                    assert_eq!(flat.accept_lens, paged.accept_lens, "acceptance diverged");
                    assert_eq!(flat.rounds, paged.rounds, "round count diverged");
                }
            }
        }
    }
}

#[test]
fn paged_residency_tracks_context_not_capacity() {
    let p = prompt(20, 12);
    let mut cfg = RunConfig::default();
    cfg.cache_layout = CacheLayout::Paged;
    let mut b = SimBackend::new(90);
    let mut e = Engine::new(&b, cfg.clone());
    let before = e.kv_bytes_resident();
    assert_eq!(before, 0, "an idle paged engine must map no blocks");
    e.generate_speculative(&mut b, &p, 16).unwrap();
    let after = e.kv_bytes_resident();
    assert!(after > 0);

    let mut fcfg = cfg.clone();
    fcfg.cache_layout = CacheLayout::Flat;
    let fe = Engine::new(&b, fcfg);
    assert!(
        after < fe.kv_bytes_resident() / 4,
        "paged residency ({after} B) must be far below the flat pinned buffers ({} B)",
        fe.kv_bytes_resident()
    );
    // reset returns every block
    e.reset();
    assert_eq!(e.kv_bytes_resident(), 0);
}

#[test]
fn scheduler_park_and_resume_matches_dedicated_engine() {
    // Conversation 0 decodes turn 1, parks (its next prompt "isn't ready"),
    // conversation 1 takes the single slot, then conversation 0 resumes
    // turn 2 on its preserved context — outputs must equal a dedicated
    // two-turn engine, with no re-prefill of turn-1 context.
    for layout in [CacheLayout::Flat, CacheLayout::Paged] {
        let agree = 85u64;
        let p1 = prompt(12, 31);
        let p2 = prompt(6, 32);
        let other = prompt(9, 33);

        // dedicated references
        let mut rb = SimBackend::new(agree);
        let mut cfg = RunConfig::default();
        cfg.cache_layout = layout;
        let mut re = Engine::new(&rb, cfg.clone());
        let want1 = re.generate_speculative(&mut rb, &p1, 10).unwrap();
        let want2 = re.generate_speculative(&mut rb, &p2, 10).unwrap();
        let mut ob = SimBackend::new(agree);
        let mut oe = Engine::new(&ob, cfg.clone());
        let want_other = oe.generate_speculative(&mut ob, &other, 8).unwrap();

        // one slot, park between the turns
        let mut bk = SimBackend::new(agree);
        let mut engines = vec![Engine::new(&bk, cfg.clone())];
        let cap = bk.contract().cache_cap;
        let mut sched = ContinuousScheduler::new(1, cap);
        sched.submit(SlotRequest { id: 0, prompt: p1.clone(), max_new: 10, cfg: None, slo: None });
        let mut turn1: Option<GenOut> = None;
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
                turn1 = Some(c.out);
                Disposition::Park
            })
            .unwrap();
        assert_eq!(sched.parked_count(), 1);
        assert_eq!(sched.stats.parked, 1);

        // the freed slot serves someone else while 0 is parked
        sched.submit(SlotRequest {
            id: 1,
            prompt: other.clone(),
            max_new: 8,
            cfg: None,
            slo: None,
        });
        let mut got_other: Option<GenOut> = None;
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
                got_other = Some(c.out);
                Disposition::Release
            })
            .unwrap();

        // resume conversation 0's turn 2
        sched.resume(0, p2.clone(), 10).unwrap();
        assert_eq!(sched.parked_count(), 0);
        let mut turn2: Option<GenOut> = None;
        sched
            .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
                turn2 = Some(c.out);
                Disposition::Release
            })
            .unwrap();

        let turn1 = turn1.unwrap();
        let turn2 = turn2.unwrap();
        assert_eq!(turn1.tokens, want1.tokens, "turn 1 diverged ({layout:?})");
        assert_eq!(got_other.unwrap().tokens, want_other.tokens, "sibling diverged ({layout:?})");
        assert_eq!(turn2.tokens, want2.tokens, "resumed turn diverged ({layout:?})");
        assert_eq!(turn2.accept_lens, want2.accept_lens);
        // no re-prefill: the resumed turn spends exactly the teacher
        // calls of a turn whose context never left its engine (re-
        // prefilling the turn-1 context would add prefill-chunk calls)
        assert_eq!(
            turn2.teacher_calls, want2.teacher_calls,
            "resume must not re-prefill the parked context ({layout:?})"
        );
        assert_eq!(sched.stats.resumed, 1);
        // resuming an unknown id is an error
        assert!(sched.resume(99, p2.clone(), 4).is_err());
    }
}

#[test]
fn set_config_switches_layouts_bit_identically() {
    // A slot engine built flat must, after set_config to paged, decode
    // exactly like a fresh paged engine (and back).
    let agree = 90u64;
    let p = prompt(13, 41);
    let mut want_cfg = RunConfig::default();
    want_cfg.cache_layout = CacheLayout::Paged;
    let mut rb = SimBackend::new(agree);
    let mut re = Engine::new(&rb, want_cfg.clone());
    let want = re.generate_speculative(&mut rb, &p, 14).unwrap();

    let mut b = SimBackend::new(agree);
    let mut e = Engine::new(&b, RunConfig::default());
    e.generate_speculative(&mut b, &prompt(7, 42), 6).unwrap(); // burn a flat conversation
    e.set_config(want_cfg);
    let got = e.generate_speculative(&mut b, &p, 14).unwrap();
    assert_eq!(got.tokens, want.tokens);
    assert_eq!(got.accept_lens, want.accept_lens);
}
