//! Load-adaptive speculation properties (the `--adaptive-occupancy`
//! contract, `docs/ARCHITECTURE.md` §13):
//!
//! 1. **Bounds** — under arbitrary interleavings of utilization
//!    observations and occupancy signals, the effective budget never
//!    escapes `[min_budget, max_budget]`, in either controller mode.
//! 2. **Monotonicity** — at a fixed utilization history, the effective
//!    budget is monotone non-increasing in the occupancy fraction (more
//!    live slot-mates can only shrink the tree, never grow it).
//! 3. **Off-path bit-identity** — with `adaptive_occupancy off` (the
//!    default), the occupancy signal is inert: the controller ignores it,
//!    and a scheduler drive (which feeds occupancy every tick) decodes
//!    token-for-token like a dedicated sequential engine, in every CI
//!    matrix cell (`EA_CACHE_LAYOUT` x `EA_PIPELINE`).
//! 4. **Output stability** — occupancy mode reshapes *budgets*, never
//!    tokens: decoded output stays exactly teacher-greedy.

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::config::{CacheLayout, RunConfig};
use eagle_pangu::coordinator::{Completion, ContinuousScheduler, Disposition, SlotRequest};
use eagle_pangu::engine::{Engine, GenOut};
use eagle_pangu::spec::AdaptiveBudget;
use eagle_pangu::util::prop;
use eagle_pangu::util::SplitMix64;

/// Base config of the CI feature matrix (mirrors `tests/continuous.rs`):
/// every adaptive property must hold identically in every cell.
fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    if let Ok(v) = std::env::var("EA_CACHE_LAYOUT") {
        cfg.cache_layout = CacheLayout::parse(&v).expect("EA_CACHE_LAYOUT must be flat|paged");
    }
    if let Ok(v) = std::env::var("EA_PIPELINE") {
        cfg.pipelining = match v.as_str() {
            "on" => true,
            "off" => false,
            _ => panic!("EA_PIPELINE must be on|off"),
        };
    }
    cfg
}

fn prompt(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = SplitMix64::new(seed);
    let mut p = vec![1i32]; // BOS
    for _ in 1..n.max(2) {
        p.push(rng.range(2, 512) as i32);
    }
    p
}

#[test]
fn property_budget_stays_in_bounds_under_arbitrary_signals() {
    prop::for_cases(40, 0xADA_901, |g| {
        let min = g.usize_in(1, 9);
        let max = min + g.usize_in(0, 64);
        let init = g.usize_in(0, 100);
        let slots = g.usize_in(1, 17);
        let mut occ = AdaptiveBudget::new(init, min, max).with_occupancy();
        let mut plain = AdaptiveBudget::new(init, min, max);
        for _ in 0..g.usize_in(1, 200) {
            if g.bool_p(0.3) {
                let live = g.usize_in(0, slots + 1);
                occ.observe_occupancy(live, slots);
                plain.observe_occupancy(live, slots);
            }
            // accept_len may even exceed the offer (defensive input)
            let offered = occ.budget().max(1);
            let accept = g.usize_in(0, offered + 2);
            occ.observe(accept, offered);
            plain.observe(accept, offered);
            for (tag, b) in [("occupancy", occ.budget()), ("plain", plain.budget())] {
                assert!(
                    (min..=max).contains(&b),
                    "{tag} budget {b} escaped [{min}, {max}]"
                );
            }
        }
    });
}

#[test]
fn property_budget_is_monotone_non_increasing_in_occupancy() {
    prop::for_cases(40, 0xADA_902, |g| {
        let mut a = AdaptiveBudget::new(g.usize_in(4, 65), 4, 64).with_occupancy();
        // drive the MIMD operating point somewhere arbitrary first
        for _ in 0..g.usize_in(0, 64) {
            let offered = a.budget().max(1);
            a.observe(g.usize_in(0, offered + 1), offered);
        }
        // then sweep occupancy upward at that fixed utilization history
        let slots = g.usize_in(2, 17);
        let mut prev = usize::MAX;
        for live in 1..=slots {
            a.observe_occupancy(live, slots);
            let b = a.budget();
            assert!(
                b <= prev,
                "budget must be monotone non-increasing in occupancy: \
                 live {live}/{slots} gave {b} after {prev}"
            );
            prev = b;
        }
        // a full batch pins the operating point at the floor
        assert_eq!(prev, 4, "full occupancy must pin the budget at min_budget");
    });
}

#[test]
fn property_occupancy_signal_is_inert_when_mode_is_off() {
    // `adaptive_occupancy off` (the default) must be bit-identical to the
    // plain adaptive controller no matter how the scheduler feeds it.
    prop::for_cases(30, 0xADA_903, |g| {
        let mut plain = AdaptiveBudget::new(16, 4, 64);
        let mut fed = AdaptiveBudget::new(16, 4, 64);
        for _ in 0..g.usize_in(1, 120) {
            if g.bool_p(0.5) {
                fed.observe_occupancy(g.usize_in(0, 9), 8);
            }
            let accept = g.usize_in(0, 20);
            let offered = g.usize_in(1, 65);
            plain.observe(accept, offered);
            fed.observe(accept, offered);
            assert_eq!(
                plain.budget(),
                fed.budget(),
                "occupancy feed must be a no-op with the mode off"
            );
        }
        assert!(!fed.occupancy_aware());
    });
}

/// Drive `reqs` through a continuous scheduler (which feeds the live-slot
/// occupancy signal to every engine each tick) and return the outputs.
fn drive(
    agree: u64,
    slots: usize,
    cfg: &RunConfig,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> Vec<GenOut> {
    let mut bk = SimBackend::new(agree);
    let mut engines: Vec<Engine> =
        (0..slots).map(|_| Engine::new(&bk, cfg.clone())).collect();
    let cap = bk.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(slots, cap);
    sched.set_pipelining(cfg.pipelining);
    for (i, p) in prompts.iter().enumerate() {
        sched.submit(SlotRequest {
            id: i as u64,
            prompt: p.clone(),
            max_new,
            cfg: Some(cfg.clone()),
            slo: None,
        });
    }
    let mut outs: Vec<Option<GenOut>> = (0..prompts.len()).map(|_| None).collect();
    sched
        .run_to_idle(&mut bk, &mut engines, &mut |c: Completion| {
            outs[c.id as usize] = Some(c.out);
            Disposition::Release
        })
        .unwrap();
    outs.into_iter().map(|o| o.expect("request completed")).collect()
}

#[test]
fn adaptive_without_occupancy_is_bit_identical_to_sequential_in_every_cell() {
    // The off-path contract behind the `adaptive_occupancy` default: the
    // scheduler feeds occupancy every tick, but with the mode off the
    // feed is inert, so a scheduled adaptive decode equals the dedicated
    // sequential adaptive decode token-for-token, round-for-round.
    let agree = 85u64;
    let mut cfg = base_cfg();
    cfg.adaptive_budget = true;
    assert!(!cfg.adaptive_occupancy, "occupancy mode must default off");
    cfg.validate().unwrap();
    let prompts: Vec<Vec<i32>> = (0..6).map(|i| prompt(8 + i * 3, 6100 + i as u64)).collect();

    let seq: Vec<GenOut> = prompts
        .iter()
        .map(|p| {
            let mut b = SimBackend::new(agree);
            let mut e = Engine::new(&b, cfg.clone());
            e.generate_speculative(&mut b, p, 14).unwrap()
        })
        .collect();
    let outs = drive(agree, 3, &cfg, &prompts, 14);
    for (i, (got, want)) in outs.iter().zip(&seq).enumerate() {
        assert_eq!(got.tokens, want.tokens, "request {i} tokens diverged with occupancy off");
        assert_eq!(got.accept_lens, want.accept_lens, "request {i} acceptance diverged");
        assert_eq!(got.rounds, want.rounds, "request {i} round count diverged");
    }
}

#[test]
fn occupancy_mode_reshapes_budgets_never_tokens() {
    // With `adaptive_occupancy on`, a full batch shrinks per-slot tree
    // budgets — but acceptance is teacher-greedy, so the decoded tokens
    // must still equal the plain adaptive sequential reference exactly.
    let agree = 85u64;
    let mut on_cfg = base_cfg();
    on_cfg.adaptive_budget = true;
    on_cfg.adaptive_occupancy = true;
    on_cfg.validate().unwrap();
    let mut off_cfg = base_cfg();
    off_cfg.adaptive_budget = true;
    let prompts: Vec<Vec<i32>> = (0..8).map(|i| prompt(10, 6400 + i as u64)).collect();

    let seq: Vec<GenOut> = prompts
        .iter()
        .map(|p| {
            let mut b = SimBackend::new(agree);
            let mut e = Engine::new(&b, off_cfg.clone());
            e.generate_speculative(&mut b, p, 16).unwrap()
        })
        .collect();
    let outs = drive(agree, 4, &on_cfg, &prompts, 16);
    for (i, (got, want)) in outs.iter().zip(&seq).enumerate() {
        assert_eq!(
            got.tokens, want.tokens,
            "request {i}: occupancy-adaptive budgets changed decoded tokens"
        );
    }
}
