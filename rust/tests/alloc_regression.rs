//! Allocation-regression tests for the zero-allocation steady-state
//! decode path, plus the engine-reuse equivalence guarantee.
//!
//! A counting global allocator (test-binary-local — integration tests are
//! separate crates, so this does not affect other test binaries) records
//! every allocation at or above `BIG` bytes. A vocab-sized logits row is
//! `512 * 4 = 2048` bytes and a cap-sized index/float vector is at least
//! that, so `BIG = 2048` catches exactly the classes of allocation the
//! tentpole eliminates (backend output blocks, mask rebuilds, logits/
//! feature clones, identity-prefix commit vectors) while ignoring small
//! bounded bookkeeping (tree nodes, accept paths, per-turn stats).

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::config::RunConfig;
use eagle_pangu::engine::Engine;
use eagle_pangu::util::SplitMix64;
use eagle_pangu::util::alloc_count::CountingAlloc;

/// Vocab row = 512 * 4 B = 2048 B; cap-sized = 1024 elements >= 4096 B.
const BIG: usize = 2048;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new(BIG);

fn prompt(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = SplitMix64::new(seed);
    let mut p = vec![1i32]; // BOS
    for _ in 1..n {
        p.push(rng.range(2, 512) as i32);
    }
    p
}

#[test]
fn steady_state_speculative_rounds_are_allocation_free() {
    let mut b = SimBackend::new(85);
    let mut e = Engine::new(&mut b, RunConfig::default());
    e.warmup().unwrap();
    // Warmup turn: brings every reusable buffer (scratches, mask slots,
    // staging buffers, candidate pool, pending/feat rows) to its
    // high-water mark.
    let p = prompt(17, 3);
    let first = e.generate_speculative(&p, 32).unwrap();
    assert!(first.rounds > 0);

    // Steady state: continue the same conversation. Every speculative
    // round must run without a single vocab- or cap-sized allocation.
    let snapshot = ALLOC.allocs();
    let cont = prompt(2, 4);
    let second = e.generate_speculative(&cont, 32).unwrap();
    assert!(second.rounds >= 4, "expected a sustained run, got {} rounds", second.rounds);
    let grew = ALLOC.allocs() - snapshot;
    assert_eq!(
        grew,
        0,
        "steady-state decode performed {grew} vocab/cap-sized allocations \
         ({} bytes) across {} rounds — the hot path regressed",
        ALLOC.bytes(),
        second.rounds
    );
}

#[test]
fn steady_state_baseline_rounds_are_allocation_free() {
    let mut b = SimBackend::new(85);
    let mut e = Engine::new(&mut b, RunConfig::default());
    e.warmup().unwrap();
    let p = prompt(12, 5);
    e.generate_baseline(&p, 24).unwrap();
    let snapshot = ALLOC.allocs();
    let cont = prompt(2, 6);
    let out = e.generate_baseline(&cont, 24).unwrap();
    assert_eq!(out.tokens.len(), 24);
    let grew = ALLOC.allocs() - snapshot;
    assert_eq!(grew, 0, "baseline decode hot path allocated ({grew} big allocations)");
}

#[test]
fn reused_engine_emits_bit_identical_tokens_to_fresh_engine() {
    // Equivalence side of engine reuse: a `reset` engine (the
    // coordinator's per-worker reuse pattern) must emit exactly the
    // tokens a freshly constructed engine emits, for both kinds.
    let p_warm = prompt(15, 7);
    let p = prompt(11, 8);

    let mut rb = SimBackend::new(85);
    let mut reused = Engine::new(&mut rb, RunConfig::default());
    reused.generate_speculative(&p_warm, 20).unwrap();
    reused.reset();
    let ea_reused = reused.generate_speculative(&p, 20).unwrap();
    reused.reset();
    let base_reused = reused.generate_baseline(&p, 20).unwrap();

    let mut fb = SimBackend::new(85);
    let mut fresh = Engine::new(&mut fb, RunConfig::default());
    let ea_fresh = fresh.generate_speculative(&p, 20).unwrap();
    let mut fb2 = SimBackend::new(85);
    let mut fresh2 = Engine::new(&mut fb2, RunConfig::default());
    let base_fresh = fresh2.generate_baseline(&p, 20).unwrap();

    assert_eq!(ea_reused.tokens, ea_fresh.tokens, "speculative reuse diverged");
    assert_eq!(ea_reused.accept_lens, ea_fresh.accept_lens);
    assert_eq!(base_reused.tokens, base_fresh.tokens, "baseline reuse diverged");
    // per-generation cache stats must also match a fresh engine (reset
    // zeroes the counters — GenOut reports one generation, not a lifetime)
    assert_eq!(ea_reused.teacher_cache, ea_fresh.teacher_cache);
    assert_eq!(ea_reused.draft_cache, ea_fresh.draft_cache);
}
