//! Allocation-regression tests for the zero-allocation steady-state
//! decode path (single-request and batched), plus the engine-reuse
//! equivalence guarantee.
//!
//! A counting global allocator (test-binary-local — integration tests are
//! separate crates, so this does not affect other test binaries) records
//! every allocation at or above `BIG` bytes. A vocab-sized logits row is
//! `512 * 4 = 2048` bytes and a cap-sized index/float vector is at least
//! that, so `BIG = 2048` catches exactly the classes of allocation the
//! hot path must not perform (backend output blocks, mask rebuilds,
//! fused gather/scatter staging, logits/feature clones, identity-prefix
//! commit vectors) while ignoring small bounded bookkeeping (tree nodes,
//! accept paths, per-round scheduling lists, per-turn stats).

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::ModelBackend;
use eagle_pangu::config::RunConfig;
use eagle_pangu::coordinator::{decode_speculative_batch, ContinuousScheduler};
use eagle_pangu::engine::Engine;
use eagle_pangu::util::SplitMix64;

// The counting allocator lives outside the library crate: its
// `unsafe impl GlobalAlloc` is incompatible with the crate-root
// `#![forbid(unsafe_code)]` invariant, and only binary/test crates can
// install a global allocator anyway. One definition, shared by path.
#[path = "support/alloc_count.rs"]
mod alloc_count;
use alloc_count::CountingAlloc;

/// Vocab row = 512 * 4 B = 2048 B; cap-sized = 1024 elements >= 4096 B.
const BIG: usize = 2048;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new(BIG);

fn prompt(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = SplitMix64::new(seed);
    let mut p = vec![1i32]; // BOS
    for _ in 1..n {
        p.push(rng.range(2, 512) as i32);
    }
    p
}

#[test]
fn steady_state_speculative_rounds_are_allocation_free() {
    let mut b = SimBackend::new(85);
    let mut e = Engine::new(&b, RunConfig::default());
    e.warmup(&mut b).unwrap();
    // Warmup turn: brings every reusable buffer (scratches, mask slots,
    // staging buffers, candidate pool, pending/feat rows) to its
    // high-water mark.
    let p = prompt(17, 3);
    let first = e.generate_speculative(&mut b, &p, 32).unwrap();
    assert!(first.rounds > 0);

    // Steady state: continue the same conversation. Every speculative
    // round must run without a single vocab- or cap-sized allocation.
    let snapshot = ALLOC.allocs();
    let cont = prompt(2, 4);
    let second = e.generate_speculative(&mut b, &cont, 32).unwrap();
    assert!(second.rounds >= 4, "expected a sustained run, got {} rounds", second.rounds);
    let grew = ALLOC.allocs() - snapshot;
    assert_eq!(
        grew,
        0,
        "steady-state decode performed {grew} vocab/cap-sized allocations \
         ({} bytes) across {} rounds — the hot path regressed",
        ALLOC.bytes(),
        second.rounds
    );
}

#[test]
fn steady_state_paged_rounds_are_allocation_free() {
    // The paged layout's half of the zero-allocation contract: after
    // warmup reserves pool headroom for one full-capacity conversation,
    // steady-state rounds map/free KV blocks purely through the free
    // list and the reserved storage — no vocab-, cap- or block-sized
    // heap allocation (block mapping is a table push + in-place writes).
    let mut cfg = RunConfig::default();
    cfg.cache_layout = eagle_pangu::config::CacheLayout::Paged;
    let mut b = SimBackend::new(85);
    let mut e = Engine::new(&b, cfg);
    e.warmup(&mut b).unwrap();
    let p = prompt(17, 7);
    let first = e.generate_speculative(&mut b, &p, 32).unwrap();
    assert!(first.rounds > 0);

    let snapshot = ALLOC.allocs();
    let cont = prompt(2, 8);
    let second = e.generate_speculative(&mut b, &cont, 32).unwrap();
    assert!(second.rounds >= 4, "expected a sustained run, got {} rounds", second.rounds);
    let grew = ALLOC.allocs() - snapshot;
    assert_eq!(
        grew,
        0,
        "steady-state paged decode performed {grew} vocab/cap/block-sized allocations \
         across {} rounds — the paged hot path regressed",
        second.rounds
    );
}

#[test]
fn steady_state_baseline_rounds_are_allocation_free() {
    let mut b = SimBackend::new(85);
    let mut e = Engine::new(&b, RunConfig::default());
    e.warmup(&mut b).unwrap();
    let p = prompt(12, 5);
    e.generate_baseline(&mut b, &p, 24).unwrap();
    let snapshot = ALLOC.allocs();
    let cont = prompt(2, 6);
    let out = e.generate_baseline(&mut b, &cont, 24).unwrap();
    assert_eq!(out.tokens.len(), 24);
    let grew = ALLOC.allocs() - snapshot;
    assert_eq!(grew, 0, "baseline decode hot path allocated ({grew} big allocations)");
}

#[test]
fn steady_state_batched_rounds_are_allocation_free() {
    // The batching-contract extension: once the scheduler's fused
    // staging (tokens/positions, the [B, S_max, cap+S_max] mask block,
    // the fused output scratch) and every engine's buffers are warmed,
    // batched rounds must be as allocation-free as single-request ones.
    const B: usize = 4;
    let mut b = SimBackend::new(85);
    let mut engines: Vec<Engine> =
        (0..B).map(|_| Engine::new(&b, RunConfig::default())).collect();
    for e in engines.iter_mut() {
        e.warmup(&mut b).unwrap();
    }
    let mut sched = ContinuousScheduler::new(B, b.contract().cache_cap);
    // this test pins the *synchronous* staging path (stage -> launch ->
    // resolve inline); the pipelined double-buffered path has its own
    // test below
    sched.set_pipelining(false);
    // Warmup drive: sizes the fused block to its high-water mark.
    let warm_prompts: Vec<Vec<i32>> = (0..B).map(|i| prompt(15, 10 + i as u64)).collect();
    let outs =
        decode_speculative_batch(&mut b, &mut engines, &warm_prompts, 24, &mut sched).unwrap();
    assert!(outs.iter().all(|o| o.rounds > 0));

    // Steady state: continue all four conversations, fused.
    let cont: Vec<Vec<i32>> = (0..B).map(|i| prompt(2, 20 + i as u64)).collect();
    let snapshot = ALLOC.allocs();
    let outs =
        decode_speculative_batch(&mut b, &mut engines, &cont, 24, &mut sched).unwrap();
    let rounds: u64 = outs.iter().map(|o| o.rounds).sum();
    assert!(rounds >= 4 * B as u64, "expected a sustained batched run, got {rounds} rounds");
    let grew = ALLOC.allocs() - snapshot;
    assert_eq!(
        grew,
        0,
        "steady-state batched decode performed {grew} vocab/cap-sized allocations \
         across {rounds} fused rounds — the batching hot path regressed"
    );
}

#[test]
fn pipelined_steady_state_rounds_are_allocation_free() {
    // The pipelined serve loop's half of the batching contract:
    // double-buffered staging means each wave stages into whichever
    // ping-pong `StageBuf` (tokens/positions, mask block, output
    // scratch) the in-flight launch is NOT holding. Once both buffers
    // have hit their high-water mark, a steady pipelined round must be
    // as allocation-free as a synchronous one.
    const B: usize = 4;
    let mut b = SimBackend::new(85);
    let mut engines: Vec<Engine> =
        (0..B).map(|_| Engine::new(&b, RunConfig::default())).collect();
    for e in engines.iter_mut() {
        e.warmup(&mut b).unwrap();
    }
    let mut sched = ContinuousScheduler::new(B, b.contract().cache_cap);
    sched.set_pipelining(true);
    // Two warmup drives: pipelined staging alternates between the two
    // StageBufs every wave, so a sustained drive sizes both — and the
    // second drive catches any buffer whose first use came late in the
    // first (e.g. the drain wave at the end of a pass).
    for w in 0..2u64 {
        let warm: Vec<Vec<i32>> =
            (0..B).map(|i| prompt(15, 40 + w * 10 + i as u64)).collect();
        let outs =
            decode_speculative_batch(&mut b, &mut engines, &warm, 24, &mut sched).unwrap();
        assert!(outs.iter().all(|o| o.rounds > 0));
    }

    // Steady state: continue all four conversations, pipelined.
    let cont: Vec<Vec<i32>> = (0..B).map(|i| prompt(2, 60 + i as u64)).collect();
    let snapshot = ALLOC.allocs();
    let outs = decode_speculative_batch(&mut b, &mut engines, &cont, 24, &mut sched).unwrap();
    let rounds: u64 = outs.iter().map(|o| o.rounds).sum();
    assert!(rounds >= 4 * B as u64, "expected a sustained pipelined run, got {rounds} rounds");
    let grew = ALLOC.allocs() - snapshot;
    assert_eq!(
        grew,
        0,
        "steady-state pipelined decode performed {grew} vocab/cap-sized allocations \
         across {rounds} rounds — the double-buffered staging path regressed"
    );
}

#[test]
fn reused_engine_emits_bit_identical_tokens_to_fresh_engine() {
    // Equivalence side of engine reuse: a `reset` engine (the
    // coordinator's per-worker reuse pattern) must emit exactly the
    // tokens a freshly constructed engine emits, for both kinds.
    let p_warm = prompt(15, 7);
    let p = prompt(11, 8);

    let mut rb = SimBackend::new(85);
    let mut reused = Engine::new(&rb, RunConfig::default());
    reused.generate_speculative(&mut rb, &p_warm, 20).unwrap();
    reused.reset();
    let ea_reused = reused.generate_speculative(&mut rb, &p, 20).unwrap();
    reused.reset();
    let base_reused = reused.generate_baseline(&mut rb, &p, 20).unwrap();

    let mut fb = SimBackend::new(85);
    let mut fresh = Engine::new(&fb, RunConfig::default());
    let ea_fresh = fresh.generate_speculative(&mut fb, &p, 20).unwrap();
    let mut fb2 = SimBackend::new(85);
    let mut fresh2 = Engine::new(&fb2, RunConfig::default());
    let base_fresh = fresh2.generate_baseline(&mut fb2, &p, 20).unwrap();

    assert_eq!(ea_reused.tokens, ea_fresh.tokens, "speculative reuse diverged");
    assert_eq!(ea_reused.accept_lens, ea_fresh.accept_lens);
    assert_eq!(base_reused.tokens, base_fresh.tokens, "baseline reuse diverged");
    // per-generation cache stats must also match a fresh engine (reset
    // zeroes the counters — GenOut reports one generation, not a lifetime)
    assert_eq!(ea_reused.teacher_cache, ea_fresh.teacher_cache);
    assert_eq!(ea_reused.draft_cache, ea_fresh.draft_cache);
}
