//! Counting global-allocator shim shared by the allocation-regression
//! test and the hot-path bench (one definition, two thresholds — the
//! counting rule must not drift between them). It lives under
//! `tests/support/` (included via `#[path]`) rather than in the library
//! because its `unsafe impl GlobalAlloc` is incompatible with the
//! crate-root `#![forbid(unsafe_code)]` invariant (see
//! `docs/STATIC_ANALYSIS.md`, rule `unsafe-code`).
//!
//! Install in a binary/test crate with:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: CountingAlloc = CountingAlloc::new(2048);
//! ```
//!
//! Counts every `alloc`/`realloc` whose (new) size is at least
//! `threshold` bytes; `threshold = 0` counts everything.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counting wrapper around the system allocator (see the module docs).
pub struct CountingAlloc {
    threshold: usize,
    allocs: AtomicU64,
    bytes: AtomicU64,
}

impl CountingAlloc {
    /// A counter recording allocations of at least `threshold` bytes.
    pub const fn new(threshold: usize) -> Self {
        Self { threshold, allocs: AtomicU64::new(0), bytes: AtomicU64::new(0) }
    }

    fn record(&self, size: usize) {
        if size >= self.threshold {
            self.allocs.fetch_add(1, Ordering::Relaxed);
            self.bytes.fetch_add(size as u64, Ordering::Relaxed);
        }
    }

    /// Number of counted allocations since process start.
    pub fn allocs(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }

    /// Bytes requested by counted allocations since process start.
    pub fn bytes(&self) -> u64 {
        self.bytes.load(Ordering::Relaxed)
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        self.record(layout.size());
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        self.record(new_size);
        System.realloc(ptr, layout, new_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholded_counting() {
        let c = CountingAlloc::new(100);
        c.record(99);
        c.record(100);
        c.record(5000);
        assert_eq!(c.allocs(), 2);
        assert_eq!(c.bytes(), 5100);
        let all = CountingAlloc::new(0);
        all.record(0);
        assert_eq!(all.allocs(), 1);
    }
}
