//! Backend-contract conformance suite: the plan → bind → execute
//! protocol, run against the deterministic [`SimBackend`] (always) and
//! the PJRT backend (artifact-gated smoke, like `tests/pjrt_smoke.rs`).
//!
//! Covered here:
//!
//! * **plan** — `plan_step` resolves the same variants the old
//!   string-keyed paths picked, and failures are *typed*
//!   (`PlanError::NoVariant` listing the compiled variants,
//!   `PlanError::SplitRequired` carrying the widest usable width) rather
//!   than `bail!` strings;
//! * **bind/execute** — session-vs-full-view bit-identity under random
//!   commit/rollback/park sequences, against both `KvStore` layouts and
//!   both branch strategies: a ticketed step reading the backend-resident
//!   mirror must reproduce the full-view step exactly, or the dirty
//!   watermark missed a mutation;
//! * **fused dispatch** — a B=4 verification tick is ONE launch when a
//!   width-4 variant exists (`launches_by_width`), and a capped
//!   capabilities table splits the group into the widest compiled
//!   launches without changing a single output token.
//!
//! The CI feature matrix runs this suite in every
//! (scheduling x cache-layout) cell; engine-level tests honor
//! `EA_CACHE_LAYOUT` the way the other matrix suites do.

use eagle_pangu::backend::sim::SimBackend;
use eagle_pangu::backend::{
    KvView, ModelBackend, ModuleLayout, ModuleRole, PlanError, PlanRequest, SessionTicket,
    StepArgs, StepScratch,
};
use eagle_pangu::cache::{CachePools, KvStore, ManagedCache, PagedCache};
use eagle_pangu::config::contract::NEG_INF;
use eagle_pangu::config::{CacheLayout, CacheStrategy, Contract, Dims, ExecMode, RunConfig};
use eagle_pangu::coordinator::{decode_speculative_batch, ContinuousScheduler};
use eagle_pangu::engine::Engine;
use eagle_pangu::util::SplitMix64;

/// Base config of the CI feature matrix: `EA_CACHE_LAYOUT` (flat | paged)
/// selects the KV layout for the engine-level tests of this suite.
fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    if let Ok(v) = std::env::var("EA_CACHE_LAYOUT") {
        cfg.cache_layout = CacheLayout::parse(&v).expect("EA_CACHE_LAYOUT must be flat|paged");
    }
    cfg
}

fn prompt(n: usize, seed: u64) -> Vec<i32> {
    let mut rng = SplitMix64::new(seed);
    let mut p = vec![1i32]; // BOS
    for _ in 1..n {
        p.push(rng.range(2, 512) as i32);
    }
    p
}

// ----------------------------------------------------------------------
// Plan negotiation
// ----------------------------------------------------------------------

#[test]
fn plan_resolves_exactly_the_old_variant_picks() {
    let b = SimBackend::new(100);
    let c = b.contract().clone();
    for rows in [1usize, 7, 8, 9, 63, 200, 256] {
        let plan = b
            .plan_step(&PlanRequest::teacher(ExecMode::Fused, rows, ModuleLayout::Flat))
            .unwrap();
        assert_eq!(plan.key.s, c.teacher_variant(rows).unwrap(), "rows={rows}");
        assert_eq!(plan.key.b, 1);
    }
    for rows in [1usize, 8, 20, 64] {
        let plan = b.plan_step(&PlanRequest::draft(rows, false, ModuleLayout::Flat)).unwrap();
        assert_eq!(plan.key.s, c.draft_variant(rows).unwrap(), "rows={rows}");
    }
}

#[test]
fn plan_failures_are_typed_with_variant_listing() {
    let b = SimBackend::new(100);
    let err = b
        .plan_step(&PlanRequest::teacher(ExecMode::Fused, 300, ModuleLayout::Flat))
        .unwrap_err();
    match &err {
        PlanError::NoVariant { available, .. } => {
            assert!(available.contains("teacher/fused"), "listing missing: {available}");
        }
        other => panic!("expected NoVariant, got {other:?}"),
    }
    let err = b
        .plan_step(&PlanRequest::draft(100, false, ModuleLayout::Flat))
        .unwrap_err();
    assert!(matches!(err, PlanError::NoVariant { .. }));
    // capped width: typed split, carrying the widest usable launch
    let capped = SimBackend::new(100).with_max_fused(3);
    let err = capped
        .plan_step(&PlanRequest::teacher_batch(ExecMode::Fused, 16, 8, ModuleLayout::Flat))
        .unwrap_err();
    assert_eq!(err, PlanError::SplitRequired { batch: 8, max_batch: 3 });
}

#[test]
fn plan_paged_requests_fall_back_to_flat_with_host_gather() {
    let b = SimBackend::new(100);
    let plan = b
        .plan_step(&PlanRequest::teacher(ExecMode::Fused, 16, ModuleLayout::Paged))
        .unwrap();
    assert_eq!(plan.key.layout, ModuleLayout::Flat);
    assert!(plan.host_gather, "paged view over flat-only modules must host-gather");
}

// ----------------------------------------------------------------------
// Session bit-identity under random op sequences (both stores)
// ----------------------------------------------------------------------

/// Build a `[L, s, H, Dh]` step-output block whose rows carry the
/// (token, position) encoding the sim's context hash reads.
fn rows_block(dims: Dims, s: usize, rows: &[(i32, i32)]) -> (Vec<f32>, Vec<f32>) {
    let rs = dims.heads * dims.d_head;
    let mut k = vec![0.0f32; dims.layers * s * rs];
    for l in 0..dims.layers {
        for (i, &(tok, pos)) in rows.iter().enumerate() {
            let off = (l * s + i) * rs;
            k[off] = tok as f32;
            k[off + 1] = pos as f32;
        }
    }
    let v = k.clone();
    (k, v)
}

/// Compare a ticketed (mirror-reading) teacher step against the same
/// step on the live view; both must be bit-identical.
fn probe_store(
    sim: &mut SimBackend,
    store: &dyn KvStore,
    ticket: SessionTicket,
    cap: usize,
) -> (Vec<f32>, Vec<f32>) {
    let s = 8usize;
    let w = cap + s;
    let rows = store.view_rows();
    let mut mask = vec![NEG_INF; s * w];
    for j in 0..rows.min(cap) {
        mask[j] = 0.0; // row 0 of the probe attends every readable row
    }
    mask[cap] = 0.0; // and itself
    let tokens = [499i32, 0, 0, 0, 0, 0, 0, 0];
    let positions = [4000i32, 0, 0, 0, 0, 0, 0, 0];
    let run = |sim: &mut SimBackend, session: Option<SessionTicket>| {
        let guard = store.kv_guard();
        let mut out = StepScratch::new();
        sim.teacher_step(
            ExecMode::Fused,
            StepArgs {
                tokens: &tokens,
                positions: &positions,
                mask: &mask,
                kv: guard.view(),
                feats_in: None,
                probe: false,
                session,
            },
            &mut out,
        )
        .unwrap();
        out.logits_row(0).to_vec()
    };
    let with_session = run(sim, Some(ticket));
    let plain = run(sim, None);
    (with_session, plain)
}

#[test]
fn session_matches_full_view_under_random_commit_rollback_park() {
    let contract = Contract::default();
    let dims = contract.teacher;
    let cap = contract.cache_cap;
    for layout in [CacheLayout::Flat, CacheLayout::Paged] {
        for strategy in [CacheStrategy::SegmentShare, CacheStrategy::DeepCopy] {
            let pools = CachePools::new(&contract);
            let mut store: Box<dyn KvStore> = match layout {
                CacheLayout::Flat => Box::new(ManagedCache::new(dims, cap, strategy, true)),
                CacheLayout::Paged => {
                    Box::new(PagedCache::new(dims, cap, strategy, true, pools.teacher.clone()))
                }
            };
            let mut sim = SimBackend::new(100);
            let sess = {
                let guard = store.kv_guard();
                sim.bind_kv(ModuleRole::Teacher, guard.view(), store.view_rows()).unwrap()
            };
            store.mark_synced();
            let mut rng = SplitMix64::new(0xC0_FF_EE ^ strategy as u64 ^ (layout as u64) << 8);
            let mut next_tok = 2i32;
            let mut branch_open = false;
            for step in 0..160 {
                let op = rng.range(0, 8);
                match op {
                    0 | 1 => {
                        if !branch_open && store.headroom() > 8 {
                            let n = rng.range(1, 4) as usize;
                            let pos0 = store.len() as i32;
                            let rows: Vec<(i32, i32)> =
                                (0..n).map(|i| (next_tok + i as i32, pos0 + i as i32)).collect();
                            next_tok = 2 + (next_tok + n as i32 - 2) % 500;
                            let (k, v) = rows_block(dims, n, &rows);
                            store.append_committed(&k, &v, n, n).unwrap();
                        }
                    }
                    2 => {
                        if !branch_open && store.headroom() > 16 {
                            store.begin_branch().unwrap();
                            branch_open = true;
                        }
                    }
                    3 | 4 => {
                        if branch_open && store.len() + store.branch_rows() + 8 < cap {
                            let n = rng.range(1, 5) as usize;
                            let pos0 = (store.len() + store.branch_rows()) as i32;
                            let rows: Vec<(i32, i32)> = (0..n)
                                .map(|i| (next_tok + i as i32, pos0 + i as i32))
                                .collect();
                            next_tok = 2 + (next_tok + n as i32 - 2) % 500;
                            let (k, v) = rows_block(dims, n, &rows);
                            store.append_branch(&k, &v, n, n).unwrap();
                        }
                    }
                    5 => {
                        if branch_open {
                            store.rollback();
                            branch_open = false;
                        }
                    }
                    6 => {
                        if branch_open {
                            let br = store.branch_rows();
                            if br == 0 || rng.range(0, 2) == 0 {
                                store.commit_length(br.min(rng.range(0, 4) as usize)).unwrap();
                            } else {
                                // strictly-increasing random tail subset
                                let tail: Vec<usize> =
                                    (0..br).filter(|_| rng.range(0, 2) == 0).collect();
                                if tail.is_empty() {
                                    store.commit_length(0).unwrap();
                                } else {
                                    store.commit_path_tail(&tail).unwrap();
                                }
                            }
                            branch_open = false;
                        }
                    }
                    _ => {
                        // "park/resume": the conversation left its slot and
                        // came back — wholesale rebind, mirror storage reused
                        let guard = store.kv_guard();
                        sim.rebind_kv(&sess, guard.view(), store.view_rows()).unwrap();
                        drop(guard);
                        store.mark_synced();
                    }
                }
                let ticket = SessionTicket {
                    id: sess.id,
                    dirty_lo: store.dirty_lo(),
                    rows: store.view_rows(),
                };
                let (with_session, plain) = probe_store(&mut sim, store.as_ref(), ticket, cap);
                assert_eq!(
                    with_session, plain,
                    "session mirror diverged from the live view at step {step} \
                     (layout {layout:?}, strategy {strategy:?}, op {op})"
                );
                store.mark_synced();
            }
            sim.unbind_kv(sess);
        }
    }
}

#[test]
fn stale_ticket_fails_typed_not_silently() {
    let contract = Contract::default();
    let n = contract.teacher.cache_elems(contract.cache_cap);
    let (k, v) = (vec![0.0f32; n], vec![0.0f32; n]);
    let mut sim = SimBackend::new(100);
    let s = 8;
    let w = contract.cache_cap + s;
    let mask = vec![NEG_INF; s * w];
    let tokens = [2i32; 8];
    let positions = [0i32; 8];
    let mut out = StepScratch::new();
    let err = sim
        .teacher_step(
            ExecMode::Fused,
            StepArgs {
                tokens: &tokens,
                positions: &positions,
                mask: &mask,
                kv: KvView::flat(&k, &v, contract.cache_cap),
                feats_in: None,
                probe: false,
                session: Some(SessionTicket { id: 777, dirty_lo: 0, rows: 0 }),
            },
            &mut out,
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("unknown KV session 777"), "{err:#}");
    // role mismatch is typed too
    let sess = sim
        .bind_kv(ModuleRole::Draft, KvView::flat(&k, &v, contract.cache_cap), 0)
        .unwrap();
    let err = sim
        .teacher_step(
            ExecMode::Fused,
            StepArgs {
                tokens: &tokens,
                positions: &positions,
                mask: &mask,
                kv: KvView::flat(&k, &v, contract.cache_cap),
                feats_in: None,
                probe: false,
                session: Some(SessionTicket { id: sess.id, dirty_lo: 0, rows: 0 }),
            },
            &mut out,
        )
        .unwrap_err();
    assert!(format!("{err:#}").contains("bound for role draft"), "{err:#}");
}

// ----------------------------------------------------------------------
// Engine-level: sessions on/off bit-identity + upload scaling
// ----------------------------------------------------------------------

#[test]
fn engine_tokens_identical_with_sessions_on_and_off() {
    let p = prompt(14, 31);
    let mut on_cfg = base_cfg();
    on_cfg.kv_sessions = true;
    let mut off_cfg = base_cfg();
    off_cfg.kv_sessions = false;

    let mut b_on = SimBackend::new(85);
    let mut e_on = Engine::new(&b_on, on_cfg);
    let out_on = e_on.generate_speculative(&mut b_on, &p, 24).unwrap();

    let mut b_off = SimBackend::new(85);
    let mut e_off = Engine::new(&b_off, off_cfg);
    let out_off = e_off.generate_speculative(&mut b_off, &p, 24).unwrap();

    assert_eq!(out_on.tokens, out_off.tokens, "sessions changed the committed text");
    assert_eq!(out_on.accept_lens, out_off.accept_lens);
    assert!(
        b_on.upload_bytes < b_off.upload_bytes / 2,
        "sessions must cut modeled upload traffic: {} vs {}",
        b_on.upload_bytes,
        b_off.upload_bytes
    );
}

#[test]
fn steady_state_session_upload_no_longer_scales_with_cap() {
    // Steady state = the second turn of a resident conversation: with a
    // bound session every step ships only its dirty delta, so the turn's
    // upload stays far below even ONE full cache pair; without sessions
    // every step re-ships the full [L, cap, H, Dh] buffers.
    let full_pair = {
        let c = Contract::default();
        ((c.teacher.cache_elems(c.cache_cap) + c.draft.cache_elems(c.cache_cap)) * 2 * 4) as u64
    };
    let mut cfg = base_cfg();
    cfg.kv_sessions = true;
    let mut b = SimBackend::new(85);
    let mut e = Engine::new(&b, cfg);
    e.generate_speculative(&mut b, &prompt(12, 41), 16).unwrap();
    let snap = b.upload_bytes;
    let turn = e.generate_speculative(&mut b, &prompt(2, 42), 16).unwrap();
    let per_token = (b.upload_bytes - snap) / turn.tokens.len().max(1) as u64;
    assert!(
        per_token < full_pair / 8,
        "session steady-state upload still cap-scaled: {per_token} B/token \
         vs full cache pair {full_pair} B"
    );
}

#[test]
fn eager_mode_stays_full_upload() {
    // the paper's two-mode design: the eager/debug path never binds
    // sessions, so its transfer is identical with the flag on or off
    let p = prompt(10, 51);
    let run = |kv_sessions: bool| {
        let mut cfg = base_cfg();
        cfg.mode = ExecMode::Eager;
        cfg.kv_sessions = kv_sessions;
        let mut b = SimBackend::new(85);
        let mut e = Engine::new(&b, cfg);
        let out = e.generate_speculative(&mut b, &p, 12).unwrap();
        (out.tokens, b.upload_bytes)
    };
    let (t_on, u_on) = run(true);
    let (t_off, u_off) = run(false);
    assert_eq!(t_on, t_off);
    assert_eq!(u_on, u_off, "eager path must not bind sessions");
}

// ----------------------------------------------------------------------
// Fused dispatch: one launch per tick; splitting preserves outputs
// ----------------------------------------------------------------------

#[test]
fn b4_verification_tick_is_one_launch() {
    let cfgs = vec![base_cfg(); 4];
    let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(10 + i, 60 + i as u64)).collect();
    let mut b = SimBackend::new(90);
    let mut engines: Vec<Engine> = cfgs.iter().map(|c| Engine::new(&b, c.clone())).collect();
    let cap = b.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(4, cap);
    // synchronous loop: this asserts the full-width one-launch-per-tick
    // contract; the pipelined loop deliberately halves steady wave
    // widths (tests/continuous.rs covers its width behaviour)
    sched.set_pipelining(false);
    decode_speculative_batch(&mut b, &mut engines, &prompts, 12, &mut sched).unwrap();
    let width4 = b.launches_by_width.get(4).copied().unwrap_or(0);
    assert!(width4 > 0, "B=4 ticks must fuse into single width-4 launches");
    assert!(
        b.launches_by_width.len() <= 5,
        "no launch may exceed the group width: {:?}",
        b.launches_by_width
    );
}

#[test]
fn capped_width_splits_group_without_changing_tokens() {
    let cfgs = vec![base_cfg(); 4];
    let prompts: Vec<Vec<i32>> = (0..4).map(|i| prompt(9 + i * 2, 80 + i as u64)).collect();

    // sequential reference
    let seq: Vec<Vec<i32>> = prompts
        .iter()
        .map(|p| {
            let mut b = SimBackend::new(88);
            let mut e = Engine::new(&b, base_cfg());
            e.generate_speculative(&mut b, p, 16).unwrap().tokens
        })
        .collect();

    // width capped at 2: the verifier must split each B=4 tick into two
    // width-2 launches (SplitRequired), never emulate sequentially
    let mut b = SimBackend::new(88).with_max_fused(2);
    let mut engines: Vec<Engine> = cfgs.iter().map(|c| Engine::new(&b, c.clone())).collect();
    let cap = b.contract().cache_cap;
    let mut sched = ContinuousScheduler::new(4, cap);
    // synchronous loop: forces the width-4 stage -> SplitRequired path
    // (pipelined waves at B=4 are already narrower than the cap; the
    // pipelined split path is covered in tests/continuous.rs)
    sched.set_pipelining(false);
    let outs = decode_speculative_batch(&mut b, &mut engines, &prompts, 16, &mut sched).unwrap();
    for (o, s) in outs.iter().zip(&seq) {
        assert_eq!(&o.tokens, s, "split launch changed tokens");
    }
    assert!(
        b.launches_by_width.get(2).copied().unwrap_or(0) > 0,
        "capped groups must fuse at the widest compiled width: {:?}",
        b.launches_by_width
    );
    assert_eq!(
        b.launches_by_width.get(3).copied().unwrap_or(0)
            + b.launches_by_width.get(4).copied().unwrap_or(0),
        0,
        "no launch may exceed the capability cap: {:?}",
        b.launches_by_width
    );
}

// ----------------------------------------------------------------------
// PJRT (artifact-gated smoke)
// ----------------------------------------------------------------------

#[test]
fn pjrt_conformance_smoke() {
    use eagle_pangu::runtime::PjrtBackend;
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return;
    }
    let mut backend = PjrtBackend::load(&dir).expect("load artifacts");
    // plan round-trips against the manifest-built capabilities table
    let plan = backend
        .plan_step(&PlanRequest::teacher(ExecMode::Fused, 9, ModuleLayout::Flat))
        .expect("compiled teacher variant");
    assert_eq!(plan.key.s, 16);
    let err = backend
        .plan_step(&PlanRequest::teacher(ExecMode::Fused, 10_000, ModuleLayout::Flat))
        .unwrap_err();
    assert!(matches!(err, PlanError::NoVariant { .. }));
    // sessions require a kv_append artifact; without one the answer is a
    // typed capability gap (callers fall back to full upload)
    let c = backend.contract().clone();
    let n = c.teacher.cache_elems(c.cache_cap);
    let (k, v) = (vec![0.0f32; n], vec![0.0f32; n]);
    let caps_has_append = backend.capabilities().supports_kv_append(ModuleRole::Teacher);
    match backend.bind_kv(ModuleRole::Teacher, KvView::flat(&k, &v, c.cache_cap), 0) {
        Ok(sess) => {
            assert!(caps_has_append, "bind must require the scatter module");
            backend.unbind_kv(sess);
        }
        Err(PlanError::SessionUnsupported { .. }) => {
            assert!(!caps_has_append, "capability table disagrees with bind_kv");
        }
        Err(other) => panic!("unexpected bind error: {other:?}"),
    }
    // a B=4 fused plan resolves iff the artifact set ships a fused
    // b-variant; when it does, executing it must be ONE module execution
    if let Ok(plan) =
        backend.plan_step(&PlanRequest::teacher_batch(ExecMode::Fused, 8, 4, ModuleLayout::Flat))
    {
        assert!(plan.key.b >= 4);
        eprintln!("fused b{}_s{} artifact present", plan.key.b, plan.key.s);
    }
}
